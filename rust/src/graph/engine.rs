//! Graph layer: the tiny-LLaMA forward pass over the kernel layer
//! (paper Fig 2: "the implementation of certain LLMs, the abstraction of
//! tensor library, basic algorithm operators, and the KV cache
//! optimization system").
//!
//! The decode loop is allocation-free: all scratch buffers are
//! pre-allocated at engine construction, the KV cache is pre-allocated
//! (see [`super::kv::KvCache`]), and weights are streamed through the
//! kernel layer's quantized dot products. The engine also *accounts* its
//! own memory traffic per step, which is what the MBU metric consumes.
//!
//! The engine decodes `batch` sequences per step: every scratch buffer is
//! sized `[batch × dim]`, and [`Engine::forward_batch`] advances all
//! sequence slots through one weight pass. The traffic ledger charges the
//! weight stream *once* per step (the batch shares it) while KV traffic
//! scales per slot — the paper's central batching effect: measured
//! bytes-per-token drops, and MBU rises, with batch size. Each slot runs
//! the exact same kernel calls as a single-sequence engine, so batched
//! logits and KV contents are bitwise identical to `batch` independent
//! engines (locked in by the parity property tests below).

use anyhow::Result;

use crate::kernel::{BackendKind, Dispatcher};
use crate::model::{LlamaConfig, ModelWeights};
use crate::quant::blocks::dequantize_row;
use crate::tensor;

use super::kv::{KvCache, KvLayout, KvPoolStats};

/// Byte-traffic ledger for one forward step (feeds MBU).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTraffic {
    pub weight_bytes: u64,
    pub kv_read_bytes: u64,
    pub kv_write_bytes: u64,
}

impl StepTraffic {
    pub fn total(&self) -> u64 {
        self.weight_bytes + self.kv_read_bytes + self.kv_write_bytes
    }
}

/// The native inference engine.
pub struct Engine {
    pub weights: ModelWeights,
    pub kernels: Dispatcher,
    pub cache: KvCache,
    cfg: LlamaConfig,
    batch: usize,
    // pre-allocated scratch, one `dim` stripe per batch slot
    // (decode loop never allocates)
    x: Vec<f32>,
    xn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    proj_out: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    ffn_out: Vec<f32>,
    scores: Vec<f32>,
    logits: Vec<f32>,
    /// Per-span final logits of `forward_spans` (stripe `i` holds the
    /// logits after span `i`'s last token).
    span_logits: Vec<f32>,
    emb_row: Vec<f32>,
    positions: Vec<usize>,
    /// Cache slot addressed by each scratch stripe of the current step
    /// (identity for `forward`/`forward_batch`; an arbitrary strictly
    /// increasing subset for `forward_slots`).
    slot_map: Vec<usize>,
}

impl Engine {
    pub fn new(weights: ModelWeights, backend: BackendKind) -> Self {
        Self::new_batched(weights, backend, 1)
    }

    /// Engine decoding `batch` sequences per step (default KV layout).
    pub fn new_batched(weights: ModelWeights, backend: BackendKind, batch: usize) -> Self {
        Self::new_batched_layout(weights, backend, batch, KvLayout::default())
    }

    /// Engine with an explicit KV storage layout — the paged/slot parity
    /// hook: [`KvLayout::Slot`] runs the retained reference layout, so
    /// serve-level tests can pin the paged allocator bitwise against it.
    pub fn new_batched_layout(
        weights: ModelWeights,
        backend: BackendKind,
        batch: usize,
        layout: KvLayout,
    ) -> Self {
        assert!(batch >= 1, "engine needs at least one sequence slot");
        let cfg = weights.config;
        let kv_dim = cfg.n_kv_heads * cfg.head_dim();
        Self {
            cache: KvCache::new_batched_layout(&cfg, batch, layout),
            kernels: Dispatcher::new(backend),
            x: vec![0.0; batch * cfg.d_model],
            xn: vec![0.0; batch * cfg.d_model],
            q: vec![0.0; batch * cfg.d_model],
            k: vec![0.0; batch * kv_dim],
            v: vec![0.0; batch * kv_dim],
            attn_out: vec![0.0; batch * cfg.d_model],
            proj_out: vec![0.0; batch * cfg.d_model],
            gate: vec![0.0; batch * cfg.d_ff],
            up: vec![0.0; batch * cfg.d_ff],
            ffn_out: vec![0.0; batch * cfg.d_model],
            scores: vec![0.0; cfg.max_seq_len],
            logits: vec![0.0; batch * cfg.vocab_size],
            span_logits: vec![0.0; batch * cfg.vocab_size],
            emb_row: vec![0.0; cfg.d_model],
            positions: Vec::with_capacity(batch),
            slot_map: Vec::with_capacity(batch),
            batch,
            cfg,
            weights,
        }
    }

    pub fn config(&self) -> &LlamaConfig {
        &self.cfg
    }

    /// Number of sequence slots this engine decodes per step.
    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn reset(&mut self) {
        self.cache.reset();
    }

    /// Release/claim one sequence slot: zero its KV length so a retired
    /// request's stale cache can never leak into a newly admitted one.
    /// Other slots keep decoding undisturbed (the continuous-batching
    /// lifecycle primitive — see the stale-KV regression test below).
    pub fn reset_slot(&mut self, slot: usize) {
        self.cache.reset_slot(slot);
    }

    /// Pin one slot's KV length to exactly `len` (shrink-only) — the
    /// chat-session prefix-reuse primitive: a follow-up turn inheriting
    /// its session's slot truncates to the handed-off prefix so nothing
    /// written past it can leak into the new turn (DESIGN.md §5).
    pub fn truncate_slot(&mut self, slot: usize, len: usize) {
        self.cache.truncate_slot(slot, len);
    }

    /// Share `src`'s first `len` cached positions into the empty slot
    /// `dst` by reference (paged layout only): the prefix-sharing
    /// primitive the serve loop uses when a new request's prompt starts
    /// with tokens another slot already cached. Because the KV at a
    /// position depends only on the tokens up to it and the arithmetic
    /// is deterministic, the shared KV is bitwise identical to what
    /// recomputation would produce — sharing changes timing, never
    /// tokens. Copy-on-write keeps the chains independent afterward.
    pub fn fork_slot(&mut self, src: usize, dst: usize, len: usize) {
        self.cache.fork_slot(src, dst, len);
    }

    /// Paged-pool counters (`None` on a slot-layout engine).
    pub fn kv_pool_stats(&self) -> Option<KvPoolStats> {
        self.cache.pool_stats()
    }

    /// Run one token through the model at position `pos`; returns logits.
    /// `pos` must equal the current cache length (causal order).
    /// Single-sequence engines only; batched engines use `forward_batch`.
    pub fn forward(&mut self, token: u32, pos: usize) -> Result<&[f32]> {
        anyhow::ensure!(
            self.batch == 1,
            "forward() is single-sequence; this engine has batch {} (use forward_batch)",
            self.batch
        );
        anyhow::ensure!(
            pos == self.cache.len(),
            "forward out of order: pos {pos}, cache len {}",
            self.cache.len()
        );
        self.slot_map.clear();
        self.slot_map.push(0);
        self.step([token].as_slice())?;
        Ok(&self.logits)
    }

    /// Advance every sequence slot by one token; `tokens[s]` goes to slot
    /// `s` at that slot's current cache length. Returns `batch` logit
    /// vectors of `vocab_size` back to back.
    pub fn forward_batch(&mut self, tokens: &[u32]) -> Result<&[f32]> {
        anyhow::ensure!(
            tokens.len() == self.batch,
            "forward_batch expects {} tokens, got {}",
            self.batch,
            tokens.len()
        );
        self.slot_map.clear();
        self.slot_map.extend(0..self.batch);
        self.step(tokens)?;
        Ok(&self.logits)
    }

    /// Advance only the named slots by one token each — the continuous-
    /// batching step. `slots` must be strictly increasing and in range;
    /// `tokens[i]` goes to `slots[i]` at that slot's current cache length
    /// (positions are ragged across slots). Non-listed slots are untouched.
    /// Returns `slots.len()` logit vectors of `vocab_size` back to back,
    /// in `slots` order. Per slot the exact same kernel calls are issued
    /// as by a single-sequence engine, so logits and KV contents are
    /// independent of which other slots share the step.
    pub fn forward_slots(&mut self, slots: &[usize], tokens: &[u32]) -> Result<&[f32]> {
        anyhow::ensure!(!slots.is_empty(), "forward_slots needs at least one slot");
        anyhow::ensure!(
            tokens.len() == slots.len(),
            "forward_slots expects {} tokens, got {}",
            slots.len(),
            tokens.len()
        );
        anyhow::ensure!(
            slots.windows(2).all(|w| w[0] < w[1]),
            "forward_slots slots must be strictly increasing (got {slots:?})"
        );
        anyhow::ensure!(
            *slots.last().unwrap() < self.batch,
            "forward_slots slot {} >= batch {}",
            slots.last().unwrap(),
            self.batch
        );
        self.slot_map.clear();
        self.slot_map.extend_from_slice(slots);
        self.step(tokens)?;
        Ok(&self.logits[..tokens.len() * self.cfg.vocab_size])
    }

    /// Advance each named slot by a *range* of tokens in one scheduling
    /// step — the chunked-prefill primitive (DESIGN.md §5): `spans[i]`
    /// is fed to `slots[i]` starting at that slot's current cache
    /// length, so a prefilling request can consume a bounded chunk of
    /// its prompt while decode neighbors advance their usual one token.
    /// `slots` must be strictly increasing and in range, spans must be
    /// non-empty. Returns `slots.len()` logit vectors of `vocab_size`
    /// back to back: stripe `i` holds the logits after span `i`'s *last*
    /// token.
    ///
    /// Internally the span tokens are driven through the same per-token
    /// kernel calls as [`forward_slots`](Self::forward_slots), so logits
    /// and KV contents are bitwise identical to feeding the tokens one
    /// step at a time — chunking changes how steps are *priced*
    /// ([`traffic_for_spans`](Self::traffic_for_spans) charges the
    /// weight stream once per step), never what is computed.
    pub fn forward_spans(&mut self, slots: &[usize], spans: &[&[u32]]) -> Result<&[f32]> {
        anyhow::ensure!(!slots.is_empty(), "forward_spans needs at least one slot");
        anyhow::ensure!(
            spans.len() == slots.len(),
            "forward_spans expects {} spans, got {}",
            slots.len(),
            spans.len()
        );
        anyhow::ensure!(
            slots.windows(2).all(|w| w[0] < w[1]),
            "forward_spans slots must be strictly increasing (got {slots:?})"
        );
        anyhow::ensure!(
            *slots.last().unwrap() < self.batch,
            "forward_spans slot {} >= batch {}",
            slots.last().unwrap(),
            self.batch
        );
        anyhow::ensure!(
            spans.iter().all(|s| !s.is_empty()),
            "forward_spans spans must be non-empty"
        );
        let vocab = self.cfg.vocab_size;
        let max_span = spans.iter().map(|s| s.len()).max().unwrap();
        let mut wave_slots: Vec<usize> = Vec::with_capacity(slots.len());
        let mut wave_toks: Vec<u32> = Vec::with_capacity(slots.len());
        for k in 0..max_span {
            wave_slots.clear();
            wave_toks.clear();
            for (i, span) in spans.iter().enumerate() {
                if k < span.len() {
                    wave_slots.push(slots[i]);
                    wave_toks.push(span[k]);
                }
            }
            self.slot_map.clear();
            self.slot_map.extend_from_slice(&wave_slots);
            self.step(&wave_toks)?;
            // Capture the logits of every span that ends on this wave.
            for (i, span) in spans.iter().enumerate() {
                if k + 1 == span.len() {
                    let w = wave_slots
                        .iter()
                        .position(|&s| s == slots[i])
                        .expect("span slot present in its final wave");
                    self.span_logits[i * vocab..(i + 1) * vocab]
                        .copy_from_slice(&self.logits[w * vocab..(w + 1) * vocab]);
                }
            }
        }
        Ok(&self.span_logits[..spans.len() * vocab])
    }

    /// One batched decode step: every weight matrix is routed through the
    /// kernel layer once, serving the `self.slot_map` slots (scratch
    /// stripe `i` addresses cache slot `slot_map[i]`).
    fn step(&mut self, tokens: &[u32]) -> Result<()> {
        let cfg = self.cfg;
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        let kv_dim = cfg.n_kv_heads * hd;
        let heads_per_kv = cfg.n_heads / cfg.n_kv_heads;
        let b = tokens.len();

        debug_assert_eq!(self.slot_map.len(), b, "slot_map out of sync with step width");
        self.positions.clear();
        for (s, token) in tokens.iter().enumerate() {
            let slot = self.slot_map[s];
            let pos = self.cache.slot_len(slot);
            anyhow::ensure!(pos < cfg.max_seq_len, "context overflow at pos {pos} (slot {slot})");
            anyhow::ensure!(
                (*token as usize) < cfg.vocab_size,
                "token {token} out of vocab (slot {slot})"
            );
            self.positions.push(pos);
        }

        // Embedding lookup (dequantize one row per slot).
        for (s, token) in tokens.iter().enumerate() {
            dequantize_row(
                self.weights.tok_emb.qtype,
                self.weights.tok_emb.row(*token as usize),
                &mut self.emb_row,
            );
            self.x[s * d..(s + 1) * d].copy_from_slice(&self.emb_row);
        }

        for l in 0..cfg.n_layers {
            // --- attention block -----------------------------------
            // All scratch work runs over the first `b` stripes only
            // (`b` can be below `batch` under continuous batching).
            self.xn[..b * d].copy_from_slice(&self.x[..b * d]);
            {
                let lw = &self.weights.layers[l];
                for s in 0..b {
                    self.kernels
                        .rmsnorm(&mut self.xn[s * d..(s + 1) * d], &lw.attn_norm, cfg.norm_eps);
                }
                self.kernels
                    .qmatvec_batch(&lw.wq, &self.xn[..b * d], &mut self.q[..b * d], b);
                self.kernels
                    .qmatvec_batch(&lw.wk, &self.xn[..b * d], &mut self.k[..b * kv_dim], b);
                self.kernels
                    .qmatvec_batch(&lw.wv, &self.xn[..b * d], &mut self.v[..b * kv_dim], b);
            }
            // RoPE on q (per head) and k (per kv head), at each slot's pos.
            for s in 0..b {
                let pos = self.positions[s];
                for h in 0..cfg.n_heads {
                    self.kernels.rope(
                        &mut self.q[s * d + h * hd..s * d + (h + 1) * hd],
                        pos,
                        cfg.rope_theta,
                    );
                }
                for h in 0..cfg.n_kv_heads {
                    self.kernels.rope(
                        &mut self.k[s * kv_dim + h * hd..s * kv_dim + (h + 1) * hd],
                        pos,
                        cfg.rope_theta,
                    );
                }
                self.cache.write_slot(
                    l,
                    self.slot_map[s],
                    pos,
                    &self.k[s * kv_dim..(s + 1) * kv_dim],
                    &self.v[s * kv_dim..(s + 1) * kv_dim],
                );
            }

            // Attention: per slot, per head over cache positions 0..=pos.
            let scale = 1.0 / (hd as f32).sqrt();
            self.attn_out[..b * d].iter_mut().for_each(|v| *v = 0.0);
            for s in 0..b {
                let slot = self.slot_map[s];
                let pos = self.positions[s];
                for h in 0..cfg.n_heads {
                    let kvh = h / heads_per_kv;
                    let qh = &self.q[s * d + h * hd..s * d + (h + 1) * hd];
                    let scores = &mut self.scores[..pos + 1];
                    for (p, sc) in scores.iter_mut().enumerate() {
                        // During this token, pos isn't advanced yet; read
                        // our own k from scratch.
                        let krow: &[f32] = if p == pos {
                            &self.k[s * kv_dim + kvh * hd..s * kv_dim + (kvh + 1) * hd]
                        } else {
                            &self.cache.k_slot_at(l, slot, p)[kvh * hd..(kvh + 1) * hd]
                        };
                        *sc = qh.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                    }
                    self.kernels.softmax(scores);
                    let out = &mut self.attn_out[s * d + h * hd..s * d + (h + 1) * hd];
                    for p in 0..=pos {
                        let w = self.scores[p];
                        if w == 0.0 {
                            continue;
                        }
                        let vrow: &[f32] = if p == pos {
                            &self.v[s * kv_dim + kvh * hd..s * kv_dim + (kvh + 1) * hd]
                        } else {
                            &self.cache.v_slot_at(l, slot, p)[kvh * hd..(kvh + 1) * hd]
                        };
                        for (o, vv) in out.iter_mut().zip(vrow) {
                            *o += w * vv;
                        }
                    }
                }
            }
            {
                let lw = &self.weights.layers[l];
                self.kernels.qmatvec_batch(
                    &lw.wo,
                    &self.attn_out[..b * d],
                    &mut self.proj_out[..b * d],
                    b,
                );
            }
            tensor::vec_add_inplace(&mut self.x[..b * d], &self.proj_out[..b * d]);

            // --- SwiGLU MLP -----------------------------------------
            self.xn[..b * d].copy_from_slice(&self.x[..b * d]);
            {
                let lw = &self.weights.layers[l];
                for s in 0..b {
                    self.kernels
                        .rmsnorm(&mut self.xn[s * d..(s + 1) * d], &lw.ffn_norm, cfg.norm_eps);
                }
                let ff = cfg.d_ff;
                self.kernels
                    .qmatvec_batch(&lw.w1, &self.xn[..b * d], &mut self.gate[..b * ff], b);
                self.kernels
                    .qmatvec_batch(&lw.w3, &self.xn[..b * d], &mut self.up[..b * ff], b);
            }
            tensor::silu_inplace(&mut self.gate[..b * cfg.d_ff]);
            tensor::vec_mul_inplace(&mut self.gate[..b * cfg.d_ff], &self.up[..b * cfg.d_ff]);
            {
                let lw = &self.weights.layers[l];
                self.kernels.qmatvec_batch(
                    &lw.w2,
                    &self.gate[..b * cfg.d_ff],
                    &mut self.ffn_out[..b * d],
                    b,
                );
            }
            tensor::vec_add_inplace(&mut self.x[..b * d], &self.ffn_out[..b * d]);
        }
        for s in 0..b {
            self.cache.advance_slot(self.slot_map[s], self.positions[s]);
        }

        // Final norm + lm head.
        self.xn[..b * d].copy_from_slice(&self.x[..b * d]);
        for s in 0..b {
            self.kernels.rmsnorm(
                &mut self.xn[s * d..(s + 1) * d],
                &self.weights.out_norm,
                cfg.norm_eps,
            );
        }
        self.kernels.qmatvec_batch(
            &self.weights.lm_head,
            &self.xn[..b * d],
            &mut self.logits[..b * cfg.vocab_size],
            b,
        );
        Ok(())
    }

    /// Byte traffic of one decode step at the *current* cache lengths.
    /// Weights stream once per step regardless of batch (each slot reads
    /// its own embedding row); every slot pays its own KV traffic.
    pub fn step_traffic(&self) -> StepTraffic {
        StepTraffic {
            weight_bytes: self.weights.bytes_per_token()
                + (self.batch as u64 - 1) * self.weights.tok_emb.row_bytes() as u64,
            kv_read_bytes: self.cache.bytes_read_per_step(),
            kv_write_bytes: (self.batch * self.cache.kv_dim * self.cache.n_layers * 4 * 2) as u64,
        }
    }

    /// Byte traffic of one continuous-batching step over only the named
    /// slots: the weight stream is still charged once (shared by however
    /// many slots are active), KV read/write only for the active slots.
    pub fn traffic_for_slots(&self, slots: &[usize]) -> StepTraffic {
        let m = slots.len() as u64;
        StepTraffic {
            weight_bytes: self.weights.bytes_per_token()
                + m.saturating_sub(1) * self.weights.tok_emb.row_bytes() as u64,
            kv_read_bytes: slots.iter().map(|&s| self.cache.slot_bytes_in_use(s)).sum(),
            kv_write_bytes: (slots.len() * self.cache.kv_dim * self.cache.n_layers * 4 * 2) as u64,
        }
    }

    /// Byte traffic of one chunked step over the named slots, where slot
    /// `i` consumed `span_lens[i]` tokens (call *after* the
    /// corresponding [`forward_spans`](Self::forward_spans), like
    /// [`traffic_for_slots`](Self::traffic_for_slots)). The weight
    /// stream is charged **once for the whole step** — every token of
    /// every span shares the same pass over the weight matrices, which
    /// is exactly the amortization that makes chunked prefill cheap on
    /// bandwidth-bound devices — while KV reads sum each span token's
    /// attention scan and KV writes scale with the total tokens fed.
    /// With all spans of length 1 this is bit-identical to
    /// `traffic_for_slots`.
    pub fn traffic_for_spans(&self, slots: &[usize], span_lens: &[usize]) -> StepTraffic {
        debug_assert_eq!(slots.len(), span_lens.len(), "span pricing shape mismatch");
        let total: u64 = span_lens.iter().map(|l| *l as u64).sum();
        let per_pos = (self.cache.kv_dim * self.cache.n_layers * 4 * 2) as u64;
        StepTraffic {
            weight_bytes: self.weights.bytes_per_token()
                + total.saturating_sub(1) * self.weights.tok_emb.row_bytes() as u64,
            // Token k of a span ending at cache length `end` sat at
            // position end-l+k and attended over end-l+k+1 positions:
            // sum_{j=end-l+1..=end} j rows of KV per layer.
            kv_read_bytes: slots
                .iter()
                .zip(span_lens)
                .map(|(&s, &l)| {
                    let end = self.cache.slot_len(s) as u64;
                    let l = l as u64;
                    per_pos * (l * end - l * (l - 1) / 2)
                })
                .sum(),
            kv_write_bytes: total * per_pos,
        }
    }

    /// FLOPs of one chunked step over the named slots (the
    /// [`traffic_for_spans`](Self::traffic_for_spans) companion): each
    /// span token pays the per-token FLOPs at its own attention length.
    /// With all spans of length 1 this is bit-identical to
    /// [`flops_for_slots`](Self::flops_for_slots).
    pub fn flops_for_spans(&self, slots: &[usize], span_lens: &[usize]) -> f64 {
        debug_assert_eq!(slots.len(), span_lens.len(), "span pricing shape mismatch");
        slots
            .iter()
            .zip(span_lens)
            .map(|(&s, &l)| {
                let end = self.cache.slot_len(s);
                (1..=l).map(|k| self.flops_for_slot_len(end - l + k)).sum::<f64>()
            })
            .sum()
    }

    /// FLOPs of one decode step (2·params for matmuls + attention terms),
    /// summed over the batch slots.
    pub fn step_flops(&self) -> f64 {
        (0..self.batch)
            .map(|s| self.flops_for_slot_len(self.cache.slot_len(s)))
            .sum()
    }

    /// FLOPs of one continuous-batching step over only the named slots.
    pub fn flops_for_slots(&self, slots: &[usize]) -> f64 {
        slots
            .iter()
            .map(|&s| self.flops_for_slot_len(self.cache.slot_len(s)))
            .sum()
    }

    /// One slot's decode-step FLOPs at cache length `len`.
    fn flops_for_slot_len(&self, len: usize) -> f64 {
        let c = &self.cfg;
        let d = c.d_model as f64;
        let kv_dim = (c.n_kv_heads * c.head_dim()) as f64;
        let matmuls = 2.0 * (d * d          // wq
            + d * kv_dim                    // wk
            + d * kv_dim                    // wv
            + d * d                         // wo
            + 3.0 * d * c.d_ff as f64); // w1,w2,w3
        let per_layer = matmuls + 4.0 * len.max(1) as f64 * d; // attn scores+mix
        c.n_layers as f64 * per_layer + 2.0 * d * c.vocab_size as f64
    }

    /// Sum of negative log-likelihoods of `tokens[1..]` given prefixes,
    /// plus the token count — the perplexity building block. Sequences
    /// longer than the context window are evaluated in non-overlapping
    /// windows (cache reset between them), the standard strided ppl
    /// protocol. Single-sequence engines only.
    pub fn sequence_nll(&mut self, tokens: &[u32]) -> Result<(f64, usize)> {
        anyhow::ensure!(tokens.len() >= 2, "need at least 2 tokens for NLL");
        let window = self.cfg.max_seq_len;
        let mut nll = 0.0;
        let mut count = 0usize;
        for chunk in tokens.chunks(window) {
            if chunk.len() < 2 {
                break;
            }
            self.reset();
            for i in 0..chunk.len() - 1 {
                let logits = self.forward(chunk[i], i)?;
                nll -= tensor::log_softmax_at(logits, chunk[i + 1] as usize);
                count += 1;
            }
        }
        Ok((nll, count))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::random_model_file;
    use crate::model::ModelWeights;
    use crate::quant::QuantType;
    use crate::testkit::{check, gen};

    fn engine(q: QuantType, backend: BackendKind) -> Engine {
        let mf = random_model_file(q, 1234);
        Engine::new(ModelWeights::load(&mf).unwrap(), backend)
    }

    #[test]
    fn forward_produces_finite_logits() {
        let mut e = engine(QuantType::F32, BackendKind::Naive);
        let logits = e.forward(42, 0).unwrap();
        assert_eq!(logits.len(), 256);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_enforces_causal_order() {
        let mut e = engine(QuantType::F32, BackendKind::Naive);
        e.forward(1, 0).unwrap();
        assert!(e.forward(2, 5).is_err(), "skipping positions must fail");
    }

    #[test]
    fn context_overflow_is_an_error_not_a_crash() {
        let mut e = engine(QuantType::Q8_0, BackendKind::Naive);
        let max = e.config().max_seq_len;
        for p in 0..max {
            e.forward(7, p).unwrap();
        }
        assert!(e.forward(7, max).is_err());
    }

    #[test]
    fn deterministic_across_runs() {
        let mut e1 = engine(QuantType::Q4_0, BackendKind::Naive);
        let mut e2 = engine(QuantType::Q4_0, BackendKind::Naive);
        let a: Vec<f32> = e1.forward(5, 0).unwrap().to_vec();
        let b: Vec<f32> = e2.forward(5, 0).unwrap().to_vec();
        assert_eq!(a, b);
    }

    #[test]
    fn backends_agree_on_logits() {
        let mut naive = engine(QuantType::Q5_1, BackendKind::Naive);
        let mut par = engine(QuantType::Q5_1, BackendKind::Parallel(4));
        let toks = [10u32, 200, 33, 7];
        let mut la = vec![];
        let mut lb = vec![];
        for (i, t) in toks.iter().enumerate() {
            la = naive.forward(*t, i).unwrap().to_vec();
            lb = par.forward(*t, i).unwrap().to_vec();
        }
        let d = crate::util::stats::max_abs_diff(&la, &lb);
        assert!(d < 1e-4, "naive vs parallel logits differ by {d}");
    }

    #[test]
    fn quantization_perturbs_but_preserves_scale() {
        let mut f32e = engine(QuantType::F32, BackendKind::Naive);
        let mut q4e = engine(QuantType::Q4_0, BackendKind::Naive);
        let a: Vec<f32> = f32e.forward(9, 0).unwrap().to_vec();
        let b: Vec<f32> = q4e.forward(9, 0).unwrap().to_vec();
        let diff = crate::util::stats::max_abs_diff(&a, &b);
        assert!(diff > 0.0, "q4_0 must differ from f32");
        let scale = a.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-6);
        assert!(diff / scale < 1.0, "q4_0 logits unrecognizable: {diff} vs {scale}");
    }

    #[test]
    fn nll_is_positive_and_near_uniform_for_random_weights() {
        let mut e = engine(QuantType::F32, BackendKind::Naive);
        let toks: Vec<u32> = (0..32).map(|i| (i * 7 + 13) % 256).collect();
        let (nll, n) = e.sequence_nll(&toks).unwrap();
        assert_eq!(n, 31);
        let ppl = (nll / n as f64).exp();
        // Untrained random model ≈ uniform over 256 tokens.
        assert!((100.0..600.0).contains(&ppl), "ppl {ppl}");
    }

    #[test]
    fn traffic_grows_with_cache() {
        let mut e = engine(QuantType::Q4_0, BackendKind::Naive);
        e.forward(1, 0).unwrap();
        let t1 = e.step_traffic();
        for p in 1..10 {
            e.forward(1, p).unwrap();
        }
        let t10 = e.step_traffic();
        assert_eq!(t1.weight_bytes, t10.weight_bytes);
        assert!(t10.kv_read_bytes > t1.kv_read_bytes);
    }

    // --------------------------------------------------- batched decode

    fn batched_engine(q: QuantType, backend: BackendKind, seed: u64, batch: usize) -> Engine {
        let mf = random_model_file(q, seed);
        Engine::new_batched(ModelWeights::load(&mf).unwrap(), backend, batch)
    }

    #[test]
    fn forward_batch_rejects_wrong_width() {
        let mut e = batched_engine(QuantType::Q8_0, BackendKind::Naive, 9, 2);
        assert!(e.forward_batch(&[1, 2, 3]).is_err());
        assert!(e.forward_batch(&[1]).is_err());
        assert!(e.forward_batch(&[1, 2]).is_ok());
    }

    #[test]
    fn forward_rejects_batched_engine() {
        let mut e = batched_engine(QuantType::Q8_0, BackendKind::Naive, 9, 2);
        assert!(e.forward(1, 0).is_err(), "forward() must demand batch 1");
    }

    #[test]
    fn identical_slots_produce_identical_logits() {
        let mut e = batched_engine(QuantType::Q4_0, BackendKind::Naive, 2, 3);
        let v = e.config().vocab_size;
        for t in [5u32, 9, 40] {
            let logits = e.forward_batch(&[t, t, t]).unwrap();
            assert_eq!(&logits[..v], &logits[v..2 * v]);
            assert_eq!(&logits[..v], &logits[2 * v..]);
        }
    }

    #[test]
    fn batched_weight_traffic_amortizes_per_token() {
        let mut e1 = batched_engine(QuantType::Q4_0, BackendKind::Naive, 4, 1);
        let mut e4 = batched_engine(QuantType::Q4_0, BackendKind::Naive, 4, 4);
        e1.forward(1, 0).unwrap();
        e4.forward_batch(&[1, 1, 1, 1]).unwrap();
        let t1 = e1.step_traffic();
        let t4 = e4.step_traffic();
        // The whole batch shares one weight pass…
        assert!(t4.weight_bytes < 4 * t1.weight_bytes);
        // …so per-token bytes drop strictly, while per-slot KV does not amortize.
        assert!(t4.total() / 4 < t1.total());
        assert_eq!(t4.kv_read_bytes, 4 * t1.kv_read_bytes);
        assert_eq!(t4.kv_write_bytes, 4 * t1.kv_write_bytes);
    }

    // ------------------------------------------- per-slot lifecycle

    #[test]
    fn forward_slots_validates_input() {
        let mut e = batched_engine(QuantType::Q8_0, BackendKind::Naive, 9, 3);
        assert!(e.forward_slots(&[], &[]).is_err(), "empty slot set");
        assert!(e.forward_slots(&[0, 1], &[1]).is_err(), "width mismatch");
        assert!(e.forward_slots(&[1, 0], &[1, 2]).is_err(), "unsorted slots");
        assert!(e.forward_slots(&[0, 0], &[1, 2]).is_err(), "duplicate slots");
        assert!(e.forward_slots(&[0, 3], &[1, 2]).is_err(), "slot out of range");
        assert!(e.forward_slots(&[0, 2], &[1, 2]).is_ok());
    }

    /// A subset step must equal the same slots' steps in a full-batch
    /// engine: ragged positions, untouched bystander slots.
    #[test]
    fn forward_slots_subset_matches_full_batch() {
        let v = 256;
        let mut sub = batched_engine(QuantType::Q4_0, BackendKind::Naive, 6, 3);
        let mut full = batched_engine(QuantType::Q4_0, BackendKind::Naive, 6, 3);
        // Warm all three slots identically.
        let warm = [7u32, 21, 40];
        sub.forward_slots(&[0, 1, 2], &warm).unwrap();
        full.forward_batch(&warm).unwrap();
        // Advance only slots 0 and 2 in `sub`.
        let l_sub = sub.forward_slots(&[0, 2], &[5, 9]).unwrap().to_vec();
        assert_eq!(l_sub.len(), 2 * v);
        // Bystander slot 1 untouched, active slots advanced raggedly.
        assert_eq!(sub.cache.slot_len(1), 1);
        assert_eq!(sub.cache.slot_len(0), 2);
        assert_eq!(sub.cache.slot_len(2), 2);
        // The same tokens through the full-batch engine (slot 1 fed a
        // dummy) give identical logits for slots 0 and 2.
        let l_full = full.forward_batch(&[5, 11, 9]).unwrap().to_vec();
        assert_eq!(&l_sub[..v], &l_full[..v], "slot 0 logits must be identical");
        assert_eq!(&l_sub[v..2 * v], &l_full[2 * v..3 * v], "slot 2 logits must be identical");
    }

    /// The serve-loop satellite regression: releasing a slot zeroes its
    /// KV length, so a newly admitted request decodes from position 0
    /// with logits identical to a fresh single-sequence engine even
    /// while a neighboring slot keeps decoding mid-flight.
    #[test]
    fn released_slot_cannot_leak_stale_kv() {
        let v = 256;
        let seed = 4;
        let mut e = batched_engine(QuantType::Q8_0, BackendKind::Naive, seed, 2);
        // Old request occupies slot 0 for three tokens; slot 1 decodes too.
        for t in [3u32, 50, 99] {
            e.forward_batch(&[t, 200]).unwrap();
        }
        assert_eq!(e.cache.slot_len(0), 3);
        // Retire slot 0, admit a new request into it.
        e.reset_slot(0);
        assert_eq!(e.cache.slot_len(0), 0, "release must zero the slot len");
        assert_eq!(e.cache.slot_len(1), 3, "bystander slot must be untouched");
        // Drive the new request interleaved with slot 1's ongoing decode.
        let mut solo = engine_with_seed(QuantType::Q8_0, BackendKind::Naive, seed);
        let fresh_prompt = [11u32, 42, 13, 7];
        for (i, t) in fresh_prompt.iter().enumerate() {
            let lb = e.forward_batch(&[*t, 150]).unwrap().to_vec();
            let ls = solo.forward(*t, i).unwrap().to_vec();
            assert_eq!(&lb[..v], &ls[..], "step {i}: stale KV leaked into the reused slot");
        }
        assert_eq!(e.cache.slot_len(0), fresh_prompt.len());
        // And the slot's KV itself matches the fresh engine bit for bit.
        for l in 0..e.cache.n_layers {
            for p in 0..fresh_prompt.len() {
                assert_eq!(e.cache.k_slot_at(l, 0, p), solo.cache.k_at(l, p));
                assert_eq!(e.cache.v_slot_at(l, 0, p), solo.cache.v_at(l, p));
            }
        }
    }

    #[test]
    fn traffic_for_slots_charges_weights_once_and_kv_per_active_slot() {
        let mut e = batched_engine(QuantType::Q4_0, BackendKind::Naive, 4, 3);
        e.forward_batch(&[1, 2, 3]).unwrap();
        e.forward_slots(&[0, 1], &[4, 5]).unwrap(); // slots 0,1 at len 2; slot 2 at len 1
        let t_all = e.step_traffic();
        let t_two = e.traffic_for_slots(&[0, 1]);
        let t_one = e.traffic_for_slots(&[2]);
        assert!(t_two.weight_bytes < t_all.weight_bytes);
        assert_eq!(t_one.weight_bytes, e.weights.bytes_per_token());
        let per_pos = (e.cache.kv_dim * e.cache.n_layers * 4 * 2) as u64;
        assert_eq!(t_two.kv_read_bytes, 4 * per_pos, "two slots × len 2");
        assert_eq!(t_one.kv_read_bytes, per_pos, "one slot × len 1");
        assert_eq!(t_two.kv_write_bytes, 2 * per_pos);
        assert_eq!(
            t_all.kv_read_bytes,
            t_two.kv_read_bytes + t_one.kv_read_bytes
        );
        // flops: subset sums to the whole.
        let f = e.flops_for_slots(&[0]) + e.flops_for_slots(&[1]) + e.flops_for_slots(&[2]);
        assert!((f - e.step_flops()).abs() < 1e-6);
    }

    fn engine_with_seed(q: QuantType, backend: BackendKind, seed: u64) -> Engine {
        let mf = random_model_file(q, seed);
        Engine::new(ModelWeights::load(&mf).unwrap(), backend)
    }

    // ------------------------------------------------- span forwarding

    #[test]
    fn forward_spans_validates_input() {
        let mut e = batched_engine(QuantType::Q8_0, BackendKind::Naive, 9, 3);
        let a: &[u32] = &[1, 2];
        let b: &[u32] = &[3];
        let empty: &[u32] = &[];
        assert!(e.forward_spans(&[], &[]).is_err(), "empty slot set");
        assert!(e.forward_spans(&[0, 1], &[a]).is_err(), "width mismatch");
        assert!(e.forward_spans(&[1, 0], &[a, b]).is_err(), "unsorted slots");
        assert!(e.forward_spans(&[0, 3], &[a, b]).is_err(), "slot out of range");
        assert!(e.forward_spans(&[0, 1], &[a, empty]).is_err(), "empty span");
        assert!(e.forward_spans(&[0, 2], &[a, b]).is_ok());
    }

    /// All-single-token spans are exactly a `forward_slots` step: same
    /// logits bitwise, same cache lengths, same priced traffic/FLOPs —
    /// the guarantee that lets the serve loop route every step through
    /// the span API without perturbing the FCFS baseline.
    #[test]
    fn single_token_spans_match_forward_slots_bitwise() {
        let mut via_spans = batched_engine(QuantType::Q4_0, BackendKind::Naive, 6, 3);
        let mut via_slots = batched_engine(QuantType::Q4_0, BackendKind::Naive, 6, 3);
        let steps: [(&[usize], &[u32]); 3] =
            [(&[0, 1, 2], &[7, 21, 40]), (&[0, 2], &[5, 9]), (&[1], &[3])];
        for (slots, toks) in steps {
            let spans: Vec<&[u32]> = toks.chunks(1).collect();
            let ls = via_spans.forward_spans(slots, &spans).unwrap().to_vec();
            let lf = via_slots.forward_slots(slots, toks).unwrap().to_vec();
            assert_eq!(ls, lf, "span step must equal slot step bitwise");
            let ones = vec![1usize; slots.len()];
            let ts = via_spans.traffic_for_spans(slots, &ones);
            let tf = via_slots.traffic_for_slots(slots);
            assert_eq!(ts.weight_bytes, tf.weight_bytes);
            assert_eq!(ts.kv_read_bytes, tf.kv_read_bytes);
            assert_eq!(ts.kv_write_bytes, tf.kv_write_bytes);
            assert_eq!(
                via_spans.flops_for_spans(slots, &ones).to_bits(),
                via_slots.flops_for_slots(slots).to_bits(),
                "span flops must equal slot flops bitwise"
            );
        }
        for s in 0..3 {
            assert_eq!(via_spans.cache.slot_len(s), via_slots.cache.slot_len(s));
        }
    }

    /// The chunked-prefill invariant (DESIGN.md §5): driving a prompt
    /// through bounded chunks computes exactly what token-at-a-time
    /// prefill computes — the logits at the final prompt position are
    /// bitwise equal and so is the KV — while the *priced* traffic
    /// amortizes the weight stream (one charge per chunk instead of one
    /// per token) and moves identical KV bytes in total.
    #[test]
    fn chunked_prefill_matches_unchunked_and_amortizes_weights() {
        let seed = 15;
        let prompt: Vec<u32> = (0..13u32).map(|i| i * 17 % 256).collect();
        for chunk in [1usize, 4, 5, 13, 32] {
            let mut chunked = batched_engine(QuantType::Q8_0, BackendKind::Naive, seed, 2);
            let mut solo = engine_with_seed(QuantType::Q8_0, BackendKind::Naive, seed);
            let mut solo_logits = Vec::new();
            for (i, t) in prompt.iter().enumerate() {
                solo_logits = solo.forward(*t, i).unwrap().to_vec();
            }
            let mut last = Vec::new();
            let mut fed = 0usize;
            let (mut weight_total, mut kv_read_total, mut kv_write_total) = (0u64, 0u64, 0u64);
            while fed < prompt.len() {
                let take = chunk.min(prompt.len() - fed);
                let span: &[u32] = &prompt[fed..fed + take];
                last = chunked.forward_spans(&[0], &[span]).unwrap().to_vec();
                let t = chunked.traffic_for_spans(&[0], &[take]);
                weight_total += t.weight_bytes;
                kv_read_total += t.kv_read_bytes;
                kv_write_total += t.kv_write_bytes;
                fed += take;
            }
            assert_eq!(fed, prompt.len(), "chunk lengths must cover the prompt exactly");
            assert_eq!(chunked.cache.slot_len(0), prompt.len());
            assert_eq!(
                last, solo_logits,
                "chunk={chunk}: final-position logits must match unchunked bitwise"
            );
            for l in 0..chunked.cache.n_layers {
                for p in 0..prompt.len() {
                    assert_eq!(chunked.cache.k_slot_at(l, 0, p), solo.cache.k_at(l, p));
                    assert_eq!(chunked.cache.v_slot_at(l, 0, p), solo.cache.v_at(l, p));
                }
            }
            // Pricing: KV totals are chunk-invariant, weights amortize.
            let per_pos = (chunked.cache.kv_dim * chunked.cache.n_layers * 4 * 2) as u64;
            let n = prompt.len() as u64;
            assert_eq!(kv_read_total, per_pos * n * (n + 1) / 2, "chunk={chunk}");
            assert_eq!(kv_write_total, per_pos * n, "chunk={chunk}");
            let steps = prompt.len().div_ceil(chunk) as u64;
            let emb = chunked.weights.tok_emb.row_bytes() as u64;
            assert_eq!(
                weight_total,
                steps * chunked.weights.bytes_per_token() + (n - steps) * emb,
                "chunk={chunk}: weights charge once per chunk step"
            );
        }
    }

    /// The chat-reuse engine guarantee: truncating a slot back to a
    /// prefix and feeding new tokens computes exactly what a fresh
    /// engine fed prefix + new tokens computes — nothing written past
    /// the truncation point can leak in.
    #[test]
    fn truncate_slot_replays_prefix_like_fresh_engine() {
        let seed = 27;
        let v = 256;
        let mut e = batched_engine(QuantType::Q4_0, BackendKind::Naive, seed, 2);
        let prefix = [3u32, 50, 99];
        let discarded = [8u32, 120];
        let cont = [11u32, 42];
        for t in prefix.iter().chain(&discarded) {
            e.forward_slots(&[0, 1], &[*t, 200]).unwrap();
        }
        assert_eq!(e.cache.slot_len(0), 5);
        e.truncate_slot(0, prefix.len());
        assert_eq!(e.cache.slot_len(0), 3, "truncate pins the reused prefix");
        assert_eq!(e.cache.slot_len(1), 5, "bystander slot untouched");
        let mut fresh = engine_with_seed(QuantType::Q4_0, BackendKind::Naive, seed);
        for (i, t) in prefix.iter().enumerate() {
            fresh.forward(*t, i).unwrap();
        }
        for (i, t) in cont.iter().enumerate() {
            let lb = e.forward_slots(&[0, 1], &[*t, 150]).unwrap().to_vec();
            let ls = fresh.forward(*t, prefix.len() + i).unwrap().to_vec();
            assert_eq!(&lb[..v], &ls[..], "step {i}: truncated slot diverged from fresh prefix");
        }
    }

    /// The batched-vs-sequential parity property (tentpole lock-in): for
    /// random models, batch sizes and token streams, `forward_batch`
    /// logits match B independent single-sequence engines within 1e-5 and
    /// per-slot KV contents are identical.
    #[test]
    fn prop_forward_batch_matches_independent_engines() {
        check("batched-vs-sequential parity", |rng, _| {
            let q = *rng.choose(&[
                QuantType::F32,
                QuantType::Q4_0,
                QuantType::Q5_1,
                QuantType::Q8_0,
            ]);
            let backend = *rng.choose(&[
                BackendKind::Naive,
                BackendKind::Parallel(2),
                BackendKind::Gpu(crate::kernel::Precision::Full),
            ]);
            let seed = rng.next_u64();
            let batch = gen::usize_in(rng, 1, 3);
            let steps = gen::usize_in(rng, 2, 5);
            let mf = random_model_file(q, seed);
            let weights = ModelWeights::load(&mf).unwrap();
            let vocab = weights.config.vocab_size;
            let mut batched = Engine::new_batched(weights, backend, batch);
            let mut singles: Vec<Engine> = (0..batch)
                .map(|_| Engine::new(ModelWeights::load(&mf).unwrap(), backend))
                .collect();
            let streams: Vec<Vec<u32>> = (0..batch)
                .map(|_| (0..steps).map(|_| rng.below(vocab as u64) as u32).collect())
                .collect();
            let mut step_tokens = vec![0u32; batch];
            let mut blogits: Vec<f32> = Vec::new();
            let mut slogits: Vec<Vec<f32>> = vec![Vec::new(); batch];
            for i in 0..steps {
                for s in 0..batch {
                    step_tokens[s] = streams[s][i];
                }
                blogits = batched.forward_batch(&step_tokens).unwrap().to_vec();
                for s in 0..batch {
                    slogits[s] = singles[s].forward(streams[s][i], i).unwrap().to_vec();
                }
            }
            for s in 0..batch {
                let d = crate::util::stats::max_abs_diff(
                    &blogits[s * vocab..(s + 1) * vocab],
                    &slogits[s],
                );
                if d > 1e-5 {
                    return Err(format!(
                        "slot {s} logits drift {d} ({} {:?} batch {batch})",
                        q.name(),
                        backend
                    ));
                }
                if batched.cache.slot_len(s) != singles[s].cache.len() {
                    return Err(format!("slot {s} cache length mismatch"));
                }
                for l in 0..batched.cache.n_layers {
                    for p in 0..steps {
                        if batched.cache.k_slot_at(l, s, p) != singles[s].cache.k_at(l, p)
                            || batched.cache.v_slot_at(l, s, p) != singles[s].cache.v_at(l, p)
                        {
                            return Err(format!(
                                "slot {s} KV mismatch at layer {l} pos {p} ({})",
                                q.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    // --------------------------------------------- paged KV lock-in

    /// The paged tentpole's graph-level guarantee: an engine on the
    /// paged layout computes bitwise the same logits and KV as one on
    /// the retained slot layout, through ragged continuous-batching
    /// steps, chunked spans, truncation and slot recycling.
    #[test]
    fn paged_engine_matches_slot_layout_engine_bitwise() {
        let seed = 31;
        let mf = random_model_file(QuantType::Q8_0, seed);
        let mut paged =
            Engine::new_batched(ModelWeights::load(&mf).unwrap(), BackendKind::Naive, 3);
        let mut slot = Engine::new_batched_layout(
            ModelWeights::load(&mf).unwrap(),
            BackendKind::Naive,
            3,
            KvLayout::Slot,
        );
        assert!(paged.kv_pool_stats().is_some());
        assert!(slot.kv_pool_stats().is_none());
        let spans_a: [&[u32]; 3] = [&[7, 21, 40, 3], &[5], &[9, 9]];
        let la = paged.forward_spans(&[0, 1, 2], &spans_a).unwrap().to_vec();
        let lb = slot.forward_spans(&[0, 1, 2], &spans_a).unwrap().to_vec();
        assert_eq!(la, lb, "span logits must match bitwise");
        paged.truncate_slot(0, 2);
        slot.truncate_slot(0, 2);
        paged.reset_slot(1);
        slot.reset_slot(1);
        let la = paged.forward_slots(&[0, 1], &[11, 13]).unwrap().to_vec();
        let lb = slot.forward_slots(&[0, 1], &[11, 13]).unwrap().to_vec();
        assert_eq!(la, lb, "post-truncate/reset logits must match bitwise");
        for s in 0..3 {
            assert_eq!(paged.cache.slot_len(s), slot.cache.slot_len(s));
            for l in 0..paged.cache.n_layers {
                for p in 0..paged.cache.slot_len(s) {
                    assert_eq!(paged.cache.k_slot_at(l, s, p), slot.cache.k_slot_at(l, s, p));
                    assert_eq!(paged.cache.v_slot_at(l, s, p), slot.cache.v_slot_at(l, s, p));
                }
            }
        }
        paged.cache.pool_invariants().unwrap();
    }

    /// Forking a cached prompt prefix into a fresh slot must continue
    /// bitwise like a slot that recomputed the prefix itself — the
    /// prefix-sharing correctness argument (KV at position p depends
    /// only on tokens 0..=p), with CoW isolating the chains after.
    #[test]
    fn forked_prefix_decodes_bitwise_like_recomputation() {
        let v = 256;
        let seed = 12;
        let mf = random_model_file(QuantType::Q4_0, seed);
        let mut e = Engine::new_batched(ModelWeights::load(&mf).unwrap(), BackendKind::Naive, 2);
        let mut solo = Engine::new_batched(ModelWeights::load(&mf).unwrap(), BackendKind::Naive, 2);
        let prefix = [3u32, 50, 99, 17, 120, 8, 77, 42, 5, 60, 31, 2, 90, 14, 25, 71, 33];
        // Slot 0 caches the prefix in both engines.
        for t in prefix {
            e.forward_slots(&[0], &[t]).unwrap();
            solo.forward_slots(&[0], &[t]).unwrap();
        }
        // `e` shares it into slot 1; `solo` recomputes it there.
        e.fork_slot(0, 1, prefix.len());
        assert_eq!(e.cache.slot_len(1), prefix.len());
        let st = e.kv_pool_stats().unwrap();
        assert_eq!(st.prefix_forks, 1);
        assert_eq!(st.shared_tokens, prefix.len());
        for t in prefix {
            solo.forward_slots(&[1], &[t]).unwrap();
        }
        // Both slots decode on, interleaved: logits stay bitwise equal,
        // including past the fork point where CoW splits the tail block.
        for (i, (ta, tb)) in [(100u32, 7u32), (4, 200), (88, 88), (1, 254)].iter().enumerate() {
            let le = e.forward_slots(&[0, 1], &[*ta, *tb]).unwrap().to_vec();
            let ls = solo.forward_slots(&[0, 1], &[*ta, *tb]).unwrap().to_vec();
            assert_eq!(&le[..v], &ls[..v], "step {i}: donor slot diverged");
            assert_eq!(&le[v..], &ls[v..], "step {i}: forked slot diverged");
        }
        assert!(
            e.kv_pool_stats().unwrap().cow_copies >= 1,
            "writes past a shared prefix must copy-on-write"
        );
        e.cache.pool_invariants().unwrap();
    }
}
