//! PJRT runtime: loads the AOT-lowered HLO text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via
//! the `xla` crate — the L3↔L2 bridge. Python never runs here.
//!
//! Two executable variants exist per build:
//! * `decode_f32.hlo.txt`  — weights fed as f32 parameters;
//! * `decode_q8_0.hlo.txt` — projection weights fed as GGML q8_0 packed
//!   bytes (exactly the EGUF payload), dequantized inside the graph by
//!   the Pallas dequant-matvec kernel.
//!
//! The PJRT path is the *validation* engine (cross-checked against the
//! native engine in tests); the native Model–Graph–Kernel engine is the
//! measured one. See DESIGN.md §7.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::gguf::ModelFile;
use crate::model::LlamaConfig;
use crate::quant::{QTensor, QuantType};
use crate::tensor;
use crate::util::json::{self, Json};

/// Parsed `model_meta.json` + artifact directory handle.
#[derive(Clone, Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub meta: Json,
    pub config: LlamaConfig,
    pub param_order: Vec<String>,
}

impl Artifacts {
    pub fn load(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("model_meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {} (run `make artifacts`)", meta_path.display()))?;
        let meta = json::parse(&text).map_err(|e| anyhow!("model_meta.json: {e}"))?;
        let config = LlamaConfig::from_json(
            meta.get("config").ok_or_else(|| anyhow!("meta missing config"))?,
        )?;
        let param_order = meta
            .get("param_order")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("meta missing param_order"))?
            .iter()
            .map(|j| j.as_str().map(str::to_string))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("param_order not strings"))?;
        Ok(Self {
            dir: dir.to_path_buf(),
            meta,
            config,
            param_order,
        })
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// The trained f32 weights container.
    pub fn weights_f32(&self) -> Result<ModelFile> {
        ModelFile::load(&self.path("tiny_llama_f32.eguf"))
    }
}

/// Which decode executable to load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PjrtVariant {
    F32,
    Q8_0,
}

impl PjrtVariant {
    fn hlo_file(&self) -> &'static str {
        match self {
            PjrtVariant::F32 => "decode_f32.hlo.txt",
            PjrtVariant::Q8_0 => "decode_q8_0.hlo.txt",
        }
    }
}

/// A compiled decode step + its weight literals + KV-cache state.
pub struct PjrtEngine {
    exe: xla::PjRtLoadedExecutable,
    pub config: LlamaConfig,
    weights: Vec<xla::Literal>,
    k_cache: xla::Literal,
    v_cache: xla::Literal,
    pos: usize,
    cache_dims: [usize; 4],
    pub variant: PjrtVariant,
}

fn f32_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, &bytes)
        .map_err(|e| anyhow!("f32 literal: {e:?}"))
}

fn u8_literal(data: &[u8], dims: &[usize]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, dims, data)
        .map_err(|e| anyhow!("u8 literal: {e:?}"))
}

fn i32_scalar(x: i32) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[],
        &x.to_le_bytes(),
    )
    .map_err(|e| anyhow!("i32 literal: {e:?}"))
}

impl PjrtEngine {
    /// Compile the chosen variant and prepare weight literals from the
    /// f32 EGUF (re-quantizing to q8_0 in-process for the Q8_0 variant —
    /// the same packer the quantization flow uses, so the PJRT graph sees
    /// byte-identical weights to the native engine).
    pub fn load(artifacts: &Artifacts, variant: PjrtVariant) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;
        let hlo_path = artifacts.path(variant.hlo_file());
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", hlo_path.display()))?;

        let mf = artifacts.weights_f32()?;
        let cfg = artifacts.config;
        let mut weights = Vec::with_capacity(artifacts.param_order.len());
        for name in &artifacts.param_order {
            let t = mf
                .get(name)
                .ok_or_else(|| anyhow!("weights missing `{name}`"))?;
            anyhow::ensure!(t.qtype == QuantType::F32, "{name}: expected f32 EGUF");
            let dense = t.dequantize();
            let lit = if name.contains("norm") {
                f32_literal(&dense, &[t.cols])?
            } else if variant == PjrtVariant::Q8_0 {
                let packed = QTensor::quantize(QuantType::Q8_0, &dense, t.rows, t.cols);
                u8_literal(&packed.data, &[t.rows, packed.row_bytes()])?
            } else {
                f32_literal(&dense, &[t.rows, t.cols])?
            };
            weights.push(lit);
        }
        let hd = cfg.head_dim();
        let cache_dims = [cfg.n_layers, cfg.max_seq_len, cfg.n_heads, hd];
        let (k_cache, v_cache) = Self::zero_caches(&cache_dims)?;
        Ok(Self {
            exe,
            config: cfg,
            weights,
            k_cache,
            v_cache,
            pos: 0,
            cache_dims,
            variant,
        })
    }

    fn zero_caches(dims: &[usize; 4]) -> Result<(xla::Literal, xla::Literal)> {
        let n: usize = dims.iter().product();
        let zeros = vec![0f32; n];
        Ok((f32_literal(&zeros, dims)?, f32_literal(&zeros, dims)?))
    }

    pub fn reset(&mut self) -> Result<()> {
        let (k, v) = Self::zero_caches(&self.cache_dims)?;
        self.k_cache = k;
        self.v_cache = v;
        self.pos = 0;
        Ok(())
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Execute one decode step; returns the logits and advances the
    /// internal KV cache.
    pub fn decode(&mut self, token: u32) -> Result<Vec<f32>> {
        anyhow::ensure!(
            self.pos < self.config.max_seq_len,
            "pjrt context overflow at pos {}",
            self.pos
        );
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(4 + self.weights.len());
        let tok = i32_scalar(token as i32)?;
        let pos = i32_scalar(self.pos as i32)?;
        args.push(&tok);
        args.push(&pos);
        args.push(&self.k_cache);
        args.push(&self.v_cache);
        for w in &self.weights {
            args.push(w);
        }
        let result = self
            .exe
            .execute(&args)
            .map_err(|e| anyhow!("pjrt execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let (logits, k, v) = result
            .to_tuple3()
            .map_err(|e| anyhow!("expected 3-tuple output: {e:?}"))?;
        self.k_cache = k;
        self.v_cache = v;
        self.pos += 1;
        logits
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))
    }

    /// NLL of tokens[1..] under the PJRT graph (perplexity building
    /// block; mirrors `graph::Engine::sequence_nll`, including the
    /// non-overlapping-window protocol for long sequences).
    pub fn sequence_nll(&mut self, tokens: &[u32]) -> Result<(f64, usize)> {
        anyhow::ensure!(tokens.len() >= 2, "need at least 2 tokens");
        let window = self.config.max_seq_len;
        let mut nll = 0.0;
        let mut count = 0;
        for chunk in tokens.chunks(window) {
            if chunk.len() < 2 {
                break;
            }
            self.reset()?;
            for i in 0..chunk.len() - 1 {
                let logits = self.decode(chunk[i])?;
                nll -= tensor::log_softmax_at(&logits, chunk[i + 1] as usize);
                count += 1;
            }
        }
        Ok((nll, count))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT integration tests that need artifacts live in
    // rust/tests/pjrt_cross_check.rs; here we only test the pure pieces.

    #[test]
    fn literal_builders_roundtrip() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let u = u8_literal(&[7, 8, 9], &[3]).unwrap();
        assert_eq!(u.to_vec::<u8>().unwrap(), vec![7, 8, 9]);
        let s = i32_scalar(-5).unwrap();
        assert_eq!(s.get_first_element::<i32>().unwrap(), -5);
    }

    #[test]
    fn variant_files() {
        assert_eq!(PjrtVariant::F32.hlo_file(), "decode_f32.hlo.txt");
        assert_eq!(PjrtVariant::Q8_0.hlo_file(), "decode_q8_0.hlo.txt");
    }

    #[test]
    fn artifacts_error_without_dir() {
        assert!(Artifacts::load(Path::new("/nonexistent-dir-elib")).is_err());
    }
}
