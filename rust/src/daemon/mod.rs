//! `elib daemon` — a wall-clock serving daemon over the sim traits
//! (DESIGN.md §10).
//!
//! Everything below `coordinator::sim` treats serving as a virtual-time
//! simulation: arrivals are seeded, steps are priced from measured byte
//! traffic and FLOPs, and the clock jumps instantaneously. This module
//! puts a real network in front of that machinery without forking it:
//!
//! * [`http`] — HTTP/1.1 framing over `std::io`, no external crates;
//! * [`codec`] — wire serialization behind a [`Codec`](codec::Codec)
//!   trait (JSON first), so the framing is testable without sockets;
//! * [`pacer`] — the wall↔virtual clock mapping: the pump thread ticks
//!   the routed [`SimLoop`](crate::coordinator::sim::SimLoop) and
//!   sleeps whenever the simulation runs ahead of wall time;
//! * [`server`] — the daemon itself: a bounded worker pool accepts
//!   OpenAI-style `POST /v1/completions` (unary or SSE streaming),
//!   feeds live prompts into the routed sim via placeholder rewriting
//!   ([`SimRun::set_request`](crate::coordinator::sim::SimRun)), and
//!   reports *measured* wall-clock TTFT/TPOT next to the ledger's
//!   *predicted* values — the live MBU cross-check;
//! * [`dashboard`] — the self-contained HTML page `GET /` serves.
//!
//! The split from the sim is deliberate: the byte/FLOP ledger keeps
//! pricing every step (predictions stay bit-deterministic), while the
//! daemon layers wall-clock measurement on top. Drift between the two
//! is the model-error signal the paper's framework exists to expose.

// A panicking worker kills live connections: request paths must return
// structured errors. `elib lint` enforces the same contract
// (request-path-unwrap); this arms clippy's version wherever a real
// toolchain runs. Tests may unwrap freely.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod codec;
pub mod dashboard;
pub mod http;
pub mod pacer;
pub mod server;

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::ServeParams;
use crate::util::stats::Summary;

pub use codec::{Codec, CompletionRequest, CompletionResponse, JsonCodec};
pub use pacer::Pacer;
pub use server::{spawn, DaemonHandle};

/// Inputs of one daemon run (`elib daemon`). The embedded
/// [`ServeParams`] supplies everything the sim needs — slots, seed,
/// scheduler, KV pool budget, device clock, thermal model — while the
/// daemon-only fields shape the network front.
#[derive(Clone, Debug)]
pub struct DaemonParams {
    /// Bind address (default loopback; use `0.0.0.0` to expose).
    pub host: String,
    /// TCP port; 0 binds an ephemeral port (tests).
    pub port: u16,
    /// Connection-handling worker threads (the pump thread is extra).
    pub workers: usize,
    /// Requests allowed to wait for a slot before new arrivals get 429
    /// + `Retry-After`. 0 = no waiting room: reject whenever every slot
    /// is busy.
    pub queue_depth: usize,
    /// Lifetime request budget: the routed sim pre-allocates this many
    /// placeholder ids at startup and the daemon answers 503 once they
    /// are spent (restart to reset — ids stay dense, reports stay
    /// well-formed).
    pub max_requests: usize,
    /// Virtual seconds per wall second. 1.0 serves in real time at the
    /// priced step costs; >1 plays the model faster than real time
    /// (tests drain a whole trace in milliseconds); <1 slows it down.
    pub pace: f64,
    /// Directory `GET /bench.json` (and fleet/cluster/daemon.json) are
    /// served from, for the dashboard's report panels.
    pub report_dir: PathBuf,
    /// The simulation identity: slots, seed, scheduler, pool budget,
    /// prefix sharing, device clock, thermal. Arrival/shape fields
    /// (`arrival_rate`, `num_requests`, `prompt_len`, …) are unused —
    /// live HTTP traffic replaces the seeded workload.
    pub serve: ServeParams,
}

impl Default for DaemonParams {
    fn default() -> Self {
        Self {
            host: "127.0.0.1".into(),
            port: 8080,
            workers: 4,
            queue_depth: 8,
            max_requests: 4096,
            pace: 1.0,
            report_dir: PathBuf::from("."),
            serve: ServeParams::default(),
        }
    }
}

impl DaemonParams {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.workers >= 1, "daemon needs at least one worker thread");
        anyhow::ensure!(self.max_requests >= 1, "daemon needs a request budget of at least 1");
        anyhow::ensure!(
            self.pace.is_finite() && self.pace > 0.0,
            "pace must be a positive, finite rate"
        );
        anyhow::ensure!(!self.host.is_empty(), "daemon bind host must not be empty");
        self.serve.validate()
    }
}

/// Live counters a [`DaemonHandle`] can snapshot at any moment — the
/// wall-clock side of the report (`report::daemon_section` renders it
/// next to the virtual-clock [`ServeReport`](crate::coordinator::ServeReport)).
#[derive(Clone, Debug)]
pub struct DaemonStats {
    /// Requests accepted into the FIFO (served + shed once drained).
    pub offered: usize,
    pub served: usize,
    /// Accepted requests shed at shutdown with a structured 503.
    pub shed: usize,
    /// Requests turned away at the door (429 queue-full, 503 draining
    /// or budget-exhausted) — never entered the FIFO.
    pub rejected: usize,
    pub uptime_secs: f64,
    /// Measured wall-clock TTFT over served requests (submit → first
    /// token on the wire).
    pub measured_ttft: Option<Summary>,
    /// Measured wall-clock TPOT over served requests with ≥2 tokens.
    pub measured_tpot: Option<Summary>,
    /// Mean of per-request `metrics::mbu_cross_check` — measured MBU
    /// inferred by rescaling the predicted value by the predicted/
    /// measured TPOT ratio. Near `pace`-invariant 1:1 when the ledger's
    /// step pricing matches reality.
    pub mbu_cross_check: Option<f64>,
    pub pace: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_validate_the_daemon_fields() {
        assert!(DaemonParams::default().validate().is_ok());
        let bad = DaemonParams { workers: 0, ..DaemonParams::default() };
        assert!(bad.validate().is_err());
        let bad = DaemonParams { max_requests: 0, ..DaemonParams::default() };
        assert!(bad.validate().is_err());
        let bad = DaemonParams { pace: 0.0, ..DaemonParams::default() };
        assert!(bad.validate().is_err());
        let bad = DaemonParams { pace: f64::NAN, ..DaemonParams::default() };
        assert!(bad.validate().is_err());
        let bad = DaemonParams { host: String::new(), ..DaemonParams::default() };
        assert!(bad.validate().is_err());
        // The embedded serve params are validated too.
        let mut bad = DaemonParams::default();
        bad.serve.slots = 0;
        assert!(bad.validate().is_err());
    }
}
