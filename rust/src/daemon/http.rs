//! Minimal HTTP/1.1 framing over `std::io` (DESIGN.md §10) — no
//! external HTTP crates offline, same policy as the JSON codec.
//!
//! Covers what the daemon serves: request parsing with hard limits
//! (request line, header count, body size), keep-alive pipelining,
//! fixed-length responses, and chunked transfer encoding for the
//! streaming completion path. The parser reads from any
//! [`BufRead`](std::io::BufRead), so every malformed-input path is unit
//! tested against in-memory buffers — no sockets required.

use std::io::{BufRead, Write};

/// Parser limits. Oversized inputs fail with a 4xx-mapped error instead
/// of unbounded allocation — the daemon faces a real network.
#[derive(Clone, Debug)]
pub struct Limits {
    /// Longest accepted request line (method + target + version).
    pub max_request_line: usize,
    /// Longest accepted single header line.
    pub max_header_line: usize,
    /// Most accepted header lines.
    pub max_headers: usize,
    /// Largest accepted request body.
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Self {
            max_request_line: 8 * 1024,
            max_header_line: 8 * 1024,
            max_headers: 64,
            max_body: 1024 * 1024,
        }
    }
}

/// A parse failure, carrying the HTTP status the response should use.
#[derive(Clone, Debug, PartialEq)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    fn new(status: u16, message: impl Into<String>) -> Self {
        Self { status, message: message.into() }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "http {}: {}", self.status, self.message)
    }
}

impl std::error::Error for HttpError {}

/// One parsed request. Header names are stored lowercased; use
/// [`header`](Self::header) for lookups.
#[derive(Clone, Debug, PartialEq)]
pub struct HttpRequest {
    pub method: String,
    pub target: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Does the client ask to close the connection after this exchange?
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }
}

/// Read one line terminated by `\n`, stripping the trailing `\r\n` (or
/// bare `\n`). `Ok(None)` on clean EOF before any byte.
fn read_line<R: BufRead>(
    r: &mut R,
    max: usize,
    what: &str,
) -> Result<Option<String>, HttpError> {
    let mut buf = Vec::new();
    loop {
        let chunk = r
            .fill_buf()
            .map_err(|e| HttpError::new(400, format!("read failed: {e}")))?;
        if chunk.is_empty() {
            // EOF. Mid-line EOF is a truncated request.
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(HttpError::new(400, format!("eof inside {what}")));
        }
        let nl = chunk.iter().position(|&b| b == b'\n');
        let take = nl.map_or(chunk.len(), |i| i + 1);
        if buf.len() + take > max + 2 {
            r.consume(take);
            return Err(HttpError::new(431, format!("{what} exceeds {max} bytes")));
        }
        buf.extend_from_slice(&chunk[..take]);
        r.consume(take);
        if nl.is_some() {
            break;
        }
    }
    while matches!(buf.last(), Some(b'\n' | b'\r')) {
        buf.pop();
    }
    String::from_utf8(buf)
        .map(Some)
        .map_err(|_| HttpError::new(400, format!("{what} is not valid utf-8")))
}

/// Parse one HTTP/1.1 request from the stream. `Ok(None)` means the
/// peer closed cleanly between requests (the keep-alive loop's normal
/// exit); every malformed input is an [`HttpError`] carrying the
/// status to answer with.
pub fn read_request<R: BufRead>(
    r: &mut R,
    limits: &Limits,
) -> Result<Option<HttpRequest>, HttpError> {
    let Some(line) = read_line(r, limits.max_request_line, "request line")? else {
        return Ok(None);
    };
    // Tolerate the empty line(s) a pipelining client may leave behind.
    let line = if line.is_empty() {
        match read_line(r, limits.max_request_line, "request line")? {
            Some(l) => l,
            None => return Ok(None),
        }
    } else {
        line
    };
    let mut parts = line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => return Err(HttpError::new(400, format!("malformed request line `{line}`"))),
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(HttpError::new(505, format!("unsupported version `{version}`")));
    }
    let mut headers = Vec::new();
    loop {
        let line = read_line(r, limits.max_header_line, "header line")?
            .ok_or_else(|| HttpError::new(400, "eof inside headers"))?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= limits.max_headers {
            return Err(HttpError::new(431, format!("more than {} headers", limits.max_headers)));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::new(400, format!("malformed header `{line}`")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let req = HttpRequest {
        method: method.to_string(),
        target: target.to_string(),
        headers,
        body: Vec::new(),
    };
    // Framing: a request that carries a body must declare its length.
    // Chunked *request* bodies are not accepted (the daemon streams
    // responses, not requests).
    let len = match req.header("content-length") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| HttpError::new(400, format!("bad content-length `{v}`")))?,
        None => {
            if req.header("transfer-encoding").is_some() {
                return Err(HttpError::new(411, "chunked request bodies unsupported"));
            }
            if req.method == "POST" || req.method == "PUT" {
                return Err(HttpError::new(411, "content-length required"));
            }
            0
        }
    };
    if len > limits.max_body {
        return Err(HttpError::new(413, format!("body exceeds {} bytes", limits.max_body)));
    }
    let mut body = vec![0u8; len];
    let mut filled = 0;
    while filled < len {
        let chunk = r
            .fill_buf()
            .map_err(|e| HttpError::new(400, format!("read failed: {e}")))?;
        if chunk.is_empty() {
            return Err(HttpError::new(400, "eof inside body"));
        }
        let take = chunk.len().min(len - filled);
        body[filled..filled + take].copy_from_slice(&chunk[..take]);
        r.consume(take);
        filled += take;
    }
    Ok(Some(HttpRequest { body, ..req }))
}

pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Write a fixed-length response. `extra` headers are emitted verbatim
/// (e.g. `("Retry-After", "3")`).
pub fn write_response(
    w: &mut dyn Write,
    status: u16,
    content_type: &str,
    extra: &[(&str, String)],
    body: &[u8],
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        status,
        status_reason(status),
        content_type,
        body.len()
    )?;
    for (k, v) in extra {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Chunked-transfer response writer for the streaming completion path:
/// emits the header block on construction, one chunk per
/// [`chunk`](Self::chunk), and the zero-length terminator on
/// [`finish`](Self::finish).
pub struct ChunkedWriter<'a> {
    w: &'a mut dyn Write,
    finished: bool,
}

impl<'a> ChunkedWriter<'a> {
    pub fn new(
        w: &'a mut dyn Write,
        status: u16,
        content_type: &str,
    ) -> std::io::Result<Self> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nCache-Control: no-store\r\n\r\n",
            status,
            status_reason(status),
            content_type
        )?;
        w.flush()?;
        Ok(Self { w, finished: false })
    }

    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    pub fn finish(mut self) -> std::io::Result<()> {
        self.finished = true;
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(input: &str) -> Result<Option<HttpRequest>, HttpError> {
        read_request(&mut Cursor::new(input.as_bytes()), &Limits::default())
    }

    #[test]
    fn parses_a_simple_get() {
        let req = parse("GET /metrics HTTP/1.1\r\nHost: x\r\nAccept: */*\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.target, "/metrics");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(req.body.is_empty());
        assert!(!req.wants_close());
    }

    #[test]
    fn parses_a_post_with_body() {
        let req = parse(
            "POST /v1/completions HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: 13\r\n\r\n{\"prompt\":\"a\"}",
        );
        // 13 bytes of the 14-byte body: framing honors the declared
        // length exactly, the rest stays in the stream.
        let req = req.unwrap().unwrap();
        assert_eq!(req.body, b"{\"prompt\":\"a\"");
    }

    #[test]
    fn clean_eof_between_requests_is_none() {
        assert_eq!(parse("").unwrap(), None);
    }

    #[test]
    fn malformed_request_lines_are_400() {
        for bad in ["GARBAGE\r\n\r\n", "GET\r\n\r\n", " / HTTP/1.1\r\n\r\n", "GET / HTTP/1.1 extra\r\n\r\n"] {
            let err = parse(bad).unwrap_err();
            assert_eq!(err.status, 400, "{bad:?} -> {err}");
        }
        let err = parse("GET / HTTP/3\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 505);
    }

    #[test]
    fn oversized_inputs_are_rejected_not_buffered() {
        let limits = Limits { max_request_line: 64, max_headers: 4, ..Limits::default() };
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(200));
        let err = read_request(&mut Cursor::new(long.as_bytes()), &limits).unwrap_err();
        assert_eq!(err.status, 431, "oversized request line");
        let many = format!(
            "GET / HTTP/1.1\r\n{}\r\n",
            (0..10).map(|i| format!("H{i}: v\r\n")).collect::<String>()
        );
        let err = read_request(&mut Cursor::new(many.as_bytes()), &limits).unwrap_err();
        assert_eq!(err.status, 431, "too many headers");
        let big_body = "POST / HTTP/1.1\r\nContent-Length: 9999999\r\n\r\n";
        let err = read_request(
            &mut Cursor::new(big_body.as_bytes()),
            &Limits { max_body: 1024, ..Limits::default() },
        )
        .unwrap_err();
        assert_eq!(err.status, 413, "oversized declared body");
    }

    #[test]
    fn posts_without_content_length_are_411() {
        let err = parse("POST /v1/completions HTTP/1.1\r\nHost: x\r\n\r\n{}").unwrap_err();
        assert_eq!(err.status, 411);
        let err =
            parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 411);
        let err = parse("POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn truncated_requests_are_400() {
        assert_eq!(parse("GET / HTTP/1.1\r\nHost: x").unwrap_err().status, 400);
        let err = parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").unwrap_err();
        assert_eq!(err.status, 400, "body shorter than declared");
    }

    #[test]
    fn pipelined_keep_alive_requests_parse_in_sequence() {
        let wire = "GET /a HTTP/1.1\r\nHost: x\r\n\r\n\
                    POST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi\
                    GET /c HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut r = Cursor::new(wire.as_bytes());
        let limits = Limits::default();
        let a = read_request(&mut r, &limits).unwrap().unwrap();
        let b = read_request(&mut r, &limits).unwrap().unwrap();
        let c = read_request(&mut r, &limits).unwrap().unwrap();
        assert_eq!((a.target.as_str(), b.target.as_str(), c.target.as_str()), ("/a", "/b", "/c"));
        assert_eq!(b.body, b"hi");
        assert!(c.wants_close());
        assert_eq!(read_request(&mut r, &limits).unwrap(), None, "clean eof after the batch");
    }

    #[test]
    fn responses_frame_correctly() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", &[("Retry-After", "3".into())], b"{}")
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{s}");
        assert!(s.contains("Retry-After: 3\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn chunked_writer_frames_and_terminates() {
        let mut out = Vec::new();
        let mut cw = ChunkedWriter::new(&mut out, 200, "text/event-stream").unwrap();
        cw.chunk(b"data: 1\n\n").unwrap();
        cw.chunk(b"").unwrap(); // dropped: would terminate early
        cw.chunk(b"data: [DONE]\n\n").unwrap();
        cw.finish().unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("Transfer-Encoding: chunked\r\n"));
        assert!(s.contains("\r\n\r\n9\r\ndata: 1\n\n\r\n"), "{s}");
        assert!(s.ends_with("e\r\ndata: [DONE]\n\n\r\n0\r\n\r\n"), "{s}");
    }
}
