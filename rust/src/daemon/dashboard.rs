//! The self-contained HTML dashboard `GET /` serves (DESIGN.md §10).
//!
//! One document, zero external assets: inline CSS, inline JS, no
//! fonts, no CDNs — it must render on an air-gapped edge device. The
//! page polls `/metrics` (the JSON-lines snapshot) every two seconds,
//! computes latency percentiles client-side from the per-request
//! lines, draws the queue-depth and MBU tails as inline SVG
//! sparklines, and — when `bench.json` / `fleet.json` / `cluster.json`
//! / `daemon.json` sit beside the daemon — summarizes them too.

/// The dashboard document. Served with `Content-Type: text/html`.
pub const DASHBOARD_HTML: &str = r##"<!DOCTYPE html>
<html lang="en">
<head>
<meta charset="utf-8">
<title>elib daemon</title>
<style>
  body { font: 14px/1.5 ui-monospace, monospace; background: #10141a; color: #d6dce6; margin: 2em auto; max-width: 72em; padding: 0 1em; }
  h1 { font-size: 1.3em; color: #7fd1b9; }
  h2 { font-size: 1.05em; color: #8ab4f8; margin-top: 1.6em; }
  table { border-collapse: collapse; margin: 0.5em 0; }
  td, th { border: 1px solid #2a3442; padding: 0.25em 0.8em; text-align: right; }
  th { color: #8ab4f8; }
  td:first-child, th:first-child { text-align: left; }
  .muted { color: #5d6b80; }
  .err { color: #e8837f; }
  svg { background: #161c26; border: 1px solid #2a3442; }
  #uplink { float: right; }
</style>
</head>
<body>
<h1>elib daemon <span id="uplink" class="muted">connecting&hellip;</span></h1>
<div id="agg" class="muted">no data yet</div>
<h2>live latency (wall-clock, measured)</h2>
<table id="lat"><tr><th>metric</th><th>n</th><th>p50</th><th>p90</th><th>p99</th><th>max</th></tr></table>
<h2>queue depth / MBU (virtual-step tail)</h2>
<svg id="spark" width="900" height="120"></svg>
<h2>dropped report files</h2>
<div id="reports" class="muted">looking for bench.json / fleet.json / cluster.json / daemon.json&hellip;</div>
<script>
"use strict";
function pct(xs, q) {
  if (!xs.length) return NaN;
  const s = xs.slice().sort((a, b) => a - b);
  const pos = q * (s.length - 1), lo = Math.floor(pos), hi = Math.ceil(pos);
  return lo === hi ? s[lo] : s[lo] * (1 - (pos - lo)) + s[hi] * (pos - lo);
}
function ms(x) { return isFinite(x) ? (x * 1e3).toFixed(1) : "—"; }
function latRow(name, xs) {
  return "<tr><td>" + name + "</td><td>" + xs.length + "</td><td>" + ms(pct(xs, 0.5)) +
    "</td><td>" + ms(pct(xs, 0.9)) + "</td><td>" + ms(pct(xs, 0.99)) + "</td><td>" +
    ms(Math.max(...xs)) + "</td></tr>";
}
function spark(el, queue, mbu) {
  const w = el.clientWidth || 900, h = el.clientHeight || 120, n = Math.max(queue.length, mbu.length, 2);
  const x = i => i / (n - 1) * (w - 8) + 4;
  const qmax = Math.max(1, ...queue), mmax = Math.max(0.01, ...mbu);
  const path = (xs, max, color) => xs.length < 2 ? "" :
    '<polyline fill="none" stroke="' + color + '" stroke-width="1.5" points="' +
    xs.map((v, i) => x(i).toFixed(1) + "," + (h - 6 - v / max * (h - 16)).toFixed(1)).join(" ") + '"/>';
  el.innerHTML = path(queue, qmax, "#e8b97f") + path(mbu, mmax, "#7fd1b9") +
    '<text x="8" y="14" fill="#e8b97f" font-size="11">queue (max ' + qmax + ')</text>' +
    '<text x="160" y="14" fill="#7fd1b9" font-size="11">mbu (max ' + mmax.toFixed(3) + ')</text>';
}
async function reports() {
  const names = ["bench.json", "fleet.json", "cluster.json", "daemon.json"];
  let html = "";
  for (const name of names) {
    try {
      const r = await fetch("/" + name);
      if (!r.ok) continue;
      const doc = await r.json();
      const agg = doc.aggregate || {};
      html += "<h3>" + name + "</h3><table><tr>";
      for (const k of ["num_requests", "output_tokens", "throughput_tok_s", "makespan_secs", "mbu_mean", "goodput"])
        if (agg[k] !== undefined && agg[k] !== null)
          html += "<td>" + k + "</td><td>" + (typeof agg[k] === "number" ? agg[k].toPrecision(5) : agg[k]) + "</td>";
      html += "</tr></table>";
    } catch (e) { /* absent file: skip */ }
  }
  document.getElementById("reports").innerHTML = html || "none found beside the daemon";
}
async function tick() {
  try {
    const r = await fetch('/metrics');
    const lines = (await r.text()).trim().split("\n").map(l => JSON.parse(l));
    const agg = lines.find(l => l.kind === "daemon") || {};
    const reqs = lines.filter(l => l.kind === "request");
    const series = lines.find(l => l.kind === "series") || { queue_depth: [], mbu: [] };
    document.getElementById("uplink").textContent = "live";
    document.getElementById("agg").innerHTML =
      "offered " + agg.offered + " &middot; served " + agg.served + " &middot; shed " + agg.shed +
      " &middot; rejected " + agg.rejected + " &middot; active " + agg.active + " &middot; queued " + agg.queued +
      " &middot; uptime " + (agg.uptime_secs || 0).toFixed(1) + "s &middot; pace " + agg.pace +
      "&times; &middot; mbu cross-check " + (agg.mbu_cross_check == null ? "—" : agg.mbu_cross_check.toFixed(3));
    const lat = document.getElementById("lat");
    lat.innerHTML = lat.rows[0].outerHTML +
      latRow("TTFT", reqs.map(r => r.measured_ttft_secs).filter(isFinite)) +
      latRow("TPOT", reqs.map(r => r.measured_tpot_secs).filter(isFinite)) +
      latRow("predicted TTFT", reqs.map(r => r.ttft_secs).filter(isFinite)) +
      latRow("predicted TPOT", reqs.map(r => r.tpot_secs).filter(isFinite));
    spark(document.getElementById("spark"), series.queue_depth, series.mbu);
  } catch (e) {
    document.getElementById("uplink").textContent = "disconnected";
    document.getElementById("uplink").className = "err";
  }
}
tick();
reports();
setInterval(tick, 2000);
setInterval(reports, 10000);
</script>
</body>
</html>
"##;

#[cfg(test)]
mod tests {
    use super::*;

    /// The dashboard must render on an air-gapped device: no external
    /// fetches, scripts, stylesheets or fonts — only same-origin paths.
    #[test]
    fn dashboard_is_self_contained() {
        assert!(!DASHBOARD_HTML.contains("http://"), "external http reference");
        assert!(!DASHBOARD_HTML.contains("https://"), "external https reference");
        assert!(!DASHBOARD_HTML.contains("//cdn"), "CDN reference");
        assert!(!DASHBOARD_HTML.contains("src=\"http"), "external script");
        assert!(DASHBOARD_HTML.contains("fetch('/metrics')"), "must poll the metrics endpoint");
        for name in ["bench.json", "fleet.json", "cluster.json", "daemon.json"] {
            assert!(DASHBOARD_HTML.contains(name), "must look for {name}");
        }
    }
}
