//! Wire serialization behind a trait (DESIGN.md §10), following the
//! remoc `CodecT` pattern: a codec turns values into bytes over any
//! `Write`/`Read`, so the daemon's request/response framing is testable
//! without sockets and a binary codec can slot in later without
//! touching the HTTP layer. JSON is the first (and default) codec —
//! the daemon's completions API is OpenAI-style JSON.

use std::io::{Read, Write};

use anyhow::{anyhow, Context, Result};

use crate::model::ByteTokenizer;
use crate::util::json::{self, Json};

/// Serializes [`Json`] values over byte streams. Object implementations
/// must be pure (no per-call state) — the daemon shares one codec
/// across all worker threads.
pub trait Codec: Send + Sync {
    /// Identity key, e.g. `"json"` (reported in `/metrics`).
    fn name(&self) -> &'static str;
    /// The `Content-Type` responses carry.
    fn content_type(&self) -> &'static str;
    /// Serialize `value` into `writer`.
    fn encode(&self, value: &Json, writer: &mut dyn Write) -> Result<()>;
    /// Deserialize one value from `reader` (reads to EOF).
    fn decode(&self, reader: &mut dyn Read) -> Result<Json>;
}

/// Compact deterministic JSON over the crate's own parser/serializer.
#[derive(Clone, Copy, Debug, Default)]
pub struct JsonCodec;

impl Codec for JsonCodec {
    fn name(&self) -> &'static str {
        "json"
    }

    fn content_type(&self) -> &'static str {
        "application/json"
    }

    fn encode(&self, value: &Json, writer: &mut dyn Write) -> Result<()> {
        writer
            .write_all(json::to_string(value).as_bytes())
            .context("codec write failed")
    }

    fn decode(&self, reader: &mut dyn Read) -> Result<Json> {
        let mut buf = String::new();
        reader.read_to_string(&mut buf).context("codec read failed")?;
        json::parse(&buf).map_err(|e| anyhow!("invalid json body: {e}"))
    }
}

/// One `POST /v1/completions` body. The prompt arrives either as text
/// (`"prompt"`, byte-tokenized) or as explicit token ids
/// (`"prompt_tokens"` — the loopback parity tests use this form to
/// compare token-for-token against a virtual-time `elib serve` run).
#[derive(Clone, Debug, PartialEq)]
pub struct CompletionRequest {
    pub prompt: Option<String>,
    pub prompt_tokens: Option<Vec<u32>>,
    /// Decode length (the request's `target_out`).
    pub max_tokens: usize,
    /// Stream tokens as server-sent events over chunked transfer?
    pub stream: bool,
}

impl CompletionRequest {
    pub const DEFAULT_MAX_TOKENS: usize = 16;

    pub fn from_json(v: &Json) -> Result<Self> {
        let prompt = v.get("prompt").and_then(Json::as_str).map(str::to_string);
        let prompt_tokens = match v.get("prompt_tokens") {
            None => None,
            Some(Json::Arr(xs)) => Some(
                xs.iter()
                    .map(|x| {
                        x.as_f64()
                            .filter(|f| f.fract() == 0.0 && *f >= 0.0)
                            .map(|f| f as u32)
                            .ok_or_else(|| anyhow!("prompt_tokens must be non-negative integers"))
                    })
                    .collect::<Result<Vec<u32>>>()?,
            ),
            Some(_) => anyhow::bail!("prompt_tokens must be an array"),
        };
        anyhow::ensure!(
            prompt.is_some() || prompt_tokens.is_some(),
            "request needs `prompt` (string) or `prompt_tokens` (array)"
        );
        let max_tokens = match v.get("max_tokens") {
            None => Self::DEFAULT_MAX_TOKENS,
            Some(x) => x
                .as_f64()
                .filter(|f| f.fract() == 0.0 && *f >= 1.0)
                .map(|f| f as usize)
                .ok_or_else(|| anyhow!("max_tokens must be a positive integer"))?,
        };
        let stream = match v.get("stream") {
            None => false,
            Some(x) => x.as_bool().ok_or_else(|| anyhow!("stream must be a boolean"))?,
        };
        Ok(Self { prompt, prompt_tokens, max_tokens, stream })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = Vec::new();
        if let Some(p) = &self.prompt {
            pairs.push(("prompt", Json::Str(p.clone())));
        }
        if let Some(ts) = &self.prompt_tokens {
            pairs.push((
                "prompt_tokens",
                Json::Arr(ts.iter().map(|&t| Json::Num(t as f64)).collect()),
            ));
        }
        pairs.push(("max_tokens", Json::Num(self.max_tokens as f64)));
        if self.stream {
            pairs.push(("stream", Json::Bool(true)));
        }
        Json::obj(pairs)
    }

    /// Resolve to engine token ids. Explicit `prompt_tokens` win over
    /// text; every id must be inside the model's vocabulary.
    pub fn tokens(&self, vocab: usize) -> Result<Vec<u32>> {
        let toks = match (&self.prompt_tokens, &self.prompt) {
            (Some(ts), _) => ts.clone(),
            (None, Some(text)) => ByteTokenizer.encode(text),
            (None, None) => anyhow::bail!("request has no prompt"),
        };
        anyhow::ensure!(!toks.is_empty(), "prompt must not be empty");
        if let Some(bad) = toks.iter().find(|&&t| t as usize >= vocab) {
            anyhow::bail!("prompt token {bad} outside vocabulary of {vocab}");
        }
        Ok(toks)
    }
}

/// One completed request as the wire sees it: the decoded text/tokens
/// plus the daemon's dual timing view — *predicted* latencies from the
/// virtual byte/FLOP ledger next to *measured* wall-clock latencies
/// (DESIGN.md §10's MBU cross-check surfaces their ratio).
#[derive(Clone, Debug, PartialEq)]
pub struct CompletionResponse {
    pub id: usize,
    pub model: String,
    pub text: String,
    pub tokens: Vec<u32>,
    pub prompt_tokens: usize,
    /// Predicted (virtual-clock) latencies.
    pub predicted_ttft_secs: f64,
    pub predicted_tpot_secs: f64,
    /// Measured wall-clock latencies.
    pub measured_ttft_secs: f64,
    pub measured_tpot_secs: f64,
}

impl CompletionResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Str(format!("cmpl-{}", self.id))),
            ("object", Json::Str("text_completion".into())),
            ("model", Json::Str(self.model.clone())),
            (
                "choices",
                Json::Arr(vec![Json::obj(vec![
                    ("index", Json::Num(0.0)),
                    ("text", Json::Str(self.text.clone())),
                    (
                        "tokens",
                        Json::Arr(self.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                    ),
                    ("finish_reason", Json::Str("length".into())),
                ])]),
            ),
            (
                "usage",
                Json::obj(vec![
                    ("prompt_tokens", Json::Num(self.prompt_tokens as f64)),
                    ("completion_tokens", Json::Num(self.tokens.len() as f64)),
                    (
                        "total_tokens",
                        Json::Num((self.prompt_tokens + self.tokens.len()) as f64),
                    ),
                ]),
            ),
            (
                "timing",
                Json::obj(vec![
                    ("predicted_ttft_secs", Json::Num(self.predicted_ttft_secs)),
                    ("predicted_tpot_secs", Json::Num(self.predicted_tpot_secs)),
                    ("measured_ttft_secs", Json::Num(self.measured_ttft_secs)),
                    ("measured_tpot_secs", Json::Num(self.measured_tpot_secs)),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let id = v
            .req_str("id")
            .map_err(|e| anyhow!("{e}"))?
            .strip_prefix("cmpl-")
            .and_then(|s| s.parse::<usize>().ok())
            .ok_or_else(|| anyhow!("bad completion id"))?;
        let choice = v
            .get("choices")
            .and_then(Json::as_arr)
            .and_then(|c| c.first())
            .ok_or_else(|| anyhow!("missing choices[0]"))?;
        let tokens = choice
            .get("tokens")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing choices[0].tokens"))?
            .iter()
            .map(|x| x.as_f64().map(|f| f as u32).ok_or_else(|| anyhow!("bad token")))
            .collect::<Result<Vec<u32>>>()?;
        let usage = v.get("usage").ok_or_else(|| anyhow!("missing usage"))?;
        let timing = v.get("timing").ok_or_else(|| anyhow!("missing timing"))?;
        Ok(Self {
            id,
            model: v.req_str("model").map_err(|e| anyhow!("{e}"))?.to_string(),
            text: choice.req_str("text").map_err(|e| anyhow!("{e}"))?.to_string(),
            tokens,
            prompt_tokens: usage.req_usize("prompt_tokens").map_err(|e| anyhow!("{e}"))?,
            predicted_ttft_secs: timing.req_f64("predicted_ttft_secs").map_err(|e| anyhow!("{e}"))?,
            predicted_tpot_secs: timing.req_f64("predicted_tpot_secs").map_err(|e| anyhow!("{e}"))?,
            measured_ttft_secs: timing.req_f64("measured_ttft_secs").map_err(|e| anyhow!("{e}"))?,
            measured_tpot_secs: timing.req_f64("measured_tpot_secs").map_err(|e| anyhow!("{e}"))?,
        })
    }
}

/// Structured error body every non-2xx response carries:
/// `{"error": {"code": ..., "message": ...}}`.
pub fn error_body(code: &str, message: &str) -> Json {
    Json::obj(vec![(
        "error",
        Json::obj(vec![
            ("code", Json::Str(code.into())),
            ("message", Json::Str(message.into())),
        ]),
    )])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, gen};

    #[test]
    fn request_parsing_validates_fields() {
        let v = json::parse(r#"{"prompt": "hi", "max_tokens": 3, "stream": true}"#).unwrap();
        let req = CompletionRequest::from_json(&v).unwrap();
        assert_eq!(req.prompt.as_deref(), Some("hi"));
        assert_eq!(req.max_tokens, 3);
        assert!(req.stream);
        assert_eq!(req.tokens(256).unwrap(), vec![104, 105]);
        for bad in [
            r#"{}"#,
            r#"{"prompt": "x", "max_tokens": 0}"#,
            r#"{"prompt": "x", "max_tokens": 1.5}"#,
            r#"{"prompt": "x", "stream": 1}"#,
            r#"{"prompt_tokens": [1, -2]}"#,
            r#"{"prompt_tokens": "x"}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(CompletionRequest::from_json(&v).is_err(), "{bad}");
        }
        // Vocabulary bound + empty prompt are caught at token resolution.
        let v = json::parse(r#"{"prompt_tokens": [999]}"#).unwrap();
        assert!(CompletionRequest::from_json(&v).unwrap().tokens(256).is_err());
        let v = json::parse(r#"{"prompt": ""}"#).unwrap();
        assert!(CompletionRequest::from_json(&v).unwrap().tokens(256).is_err());
    }

    #[test]
    fn prop_request_round_trips_through_the_codec() {
        let codec = JsonCodec;
        check("completion request codec round-trip", |rng, _case| {
            let use_text = rng.bool(0.5);
            // At least one prompt form, or the request is invalid by
            // construction.
            let use_ids = !use_text || rng.bool(0.5);
            let req = CompletionRequest {
                prompt: use_text.then(|| {
                    let n = gen::usize_in(rng, 1, 40);
                    (0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
                }),
                prompt_tokens: use_ids.then(|| {
                    let n = gen::usize_in(rng, 1, 32);
                    (0..n).map(|_| rng.below(256) as u32).collect()
                }),
                max_tokens: gen::usize_in(rng, 1, 512),
                stream: rng.bool(0.5),
            };
            let mut wire = Vec::new();
            codec.encode(&req.to_json(), &mut wire).unwrap();
            let back = codec.decode(&mut wire.as_slice()).unwrap();
            let parsed = CompletionRequest::from_json(&back)
                .map_err(|e| format!("parse-back failed: {e}"))?;
            if parsed != req {
                return Err(format!("round-trip drift: {parsed:?} != {req:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_response_round_trips_through_the_codec() {
        let codec = JsonCodec;
        check("completion response codec round-trip", |rng, _case| {
            let n = gen::usize_in(rng, 1, 24);
            let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
            let resp = CompletionResponse {
                id: gen::usize_in(rng, 0, 4095),
                model: "q8_0".into(),
                text: ByteTokenizer.decode(&tokens),
                tokens,
                prompt_tokens: gen::usize_in(rng, 1, 64),
                predicted_ttft_secs: rng.next_f64(),
                predicted_tpot_secs: rng.next_f64(),
                measured_ttft_secs: rng.next_f64(),
                measured_tpot_secs: rng.next_f64(),
            };
            let mut wire = Vec::new();
            codec.encode(&resp.to_json(), &mut wire).unwrap();
            let decoded = codec.decode(&mut wire.as_slice()).unwrap();
            let back = CompletionResponse::from_json(&decoded)
                .map_err(|e| format!("parse-back failed: {e}"))?;
            if back != resp {
                return Err(format!("round-trip drift: {back:?} != {resp:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn error_bodies_are_structured() {
        let e = error_body("queue_full", "try later");
        assert_eq!(e.at(&["error", "code"]).unwrap().as_str(), Some("queue_full"));
        assert_eq!(e.at(&["error", "message"]).unwrap().as_str(), Some("try later"));
    }
}
