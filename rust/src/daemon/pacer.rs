//! Real-time pacer: the wall-clock counterpart of the virtual
//! [`DeviceClock`](crate::device::DeviceClock) (DESIGN.md §10).
//!
//! The serving simulator prices every engine step in *virtual* seconds
//! and advances time instantaneously; the daemon keeps that ledger but
//! must release results at wall-clock speed. The pacer maps between the
//! two: `rate` virtual seconds elapse per wall second (1.0 = real
//! time), and the pump sleeps whenever the simulation runs ahead of
//! schedule. The simulation falling *behind* schedule needs no action —
//! wall time cannot be given back — which is exactly the case the
//! measured-vs-predicted TTFT/TPOT comparison exists to expose.
//!
//! All scheduling decisions are pure functions of `(rate, wall
//! elapsed, virtual now)` so they are testable without sleeping.

use std::time::{Duration, Instant};

/// Maps wall-clock time to virtual simulator time at a fixed rate.
#[derive(Clone, Debug)]
pub struct Pacer {
    start: Instant,
    rate: f64,
}

impl Pacer {
    /// `rate` virtual seconds per wall second. Values above 1.0 play
    /// the simulation faster than real time (tests use large rates so
    /// a whole trace drains in milliseconds); values below 1.0 slow it
    /// down. Must be positive and finite.
    pub fn new(rate: f64) -> Self {
        assert!(rate.is_finite() && rate > 0.0, "pace rate must be positive");
        Self { start: Instant::now(), rate }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Virtual time corresponding to "now" on the wall clock.
    pub fn virtual_now(&self) -> f64 {
        Self::virtual_at(self.start.elapsed(), self.rate)
    }

    /// Virtual time corresponding to the wall instant `at` (0.0 for
    /// instants at or before the pacer started) — stamps a request's
    /// virtual arrival from the wall instant its HTTP submit landed.
    pub fn virtual_of(&self, at: Instant) -> f64 {
        Self::virtual_at(at.saturating_duration_since(self.start), self.rate)
    }

    /// Pure mapping: virtual time after `wall` elapsed at `rate`.
    pub fn virtual_at(wall: Duration, rate: f64) -> f64 {
        wall.as_secs_f64() * rate
    }

    /// Wall seconds it takes `virtual_secs` of simulation to play out.
    pub fn wall_secs(&self, virtual_secs: f64) -> f64 {
        virtual_secs / self.rate
    }

    /// How long to sleep so the wall clock catches up with a simulation
    /// whose clock reads `sim_now` — `None` when the simulation is on
    /// or behind schedule and the next step may run immediately.
    pub fn lag(&self, sim_now: f64) -> Option<Duration> {
        Self::lag_at(sim_now, self.start.elapsed(), self.rate)
    }

    /// Pure form of [`lag`](Self::lag) for tests.
    pub fn lag_at(sim_now: f64, wall: Duration, rate: f64) -> Option<Duration> {
        let ahead = sim_now - Self::virtual_at(wall, rate);
        if ahead > 0.0 {
            Some(Duration::from_secs_f64(ahead / rate))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_time_scales_with_rate() {
        let w = Duration::from_millis(500);
        assert!((Pacer::virtual_at(w, 1.0) - 0.5).abs() < 1e-12);
        assert!((Pacer::virtual_at(w, 4.0) - 2.0).abs() < 1e-12);
        assert!((Pacer::virtual_at(w, 0.5) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn lag_is_the_wall_sleep_that_restores_schedule() {
        // Sim at 2.0 virtual s, wall at 1 s, rate 1: sim is 1 virtual
        // second ahead, which is 1 wall second of sleep.
        let lag = Pacer::lag_at(2.0, Duration::from_secs(1), 1.0).unwrap();
        assert!((lag.as_secs_f64() - 1.0).abs() < 1e-9);
        // Same lead at rate 4: virtual seconds are cheaper, sleep 0.25.
        let lag = Pacer::lag_at(6.0, Duration::from_secs(1), 4.0).unwrap();
        assert!((lag.as_secs_f64() - 0.5).abs() < 1e-9);
        // On or behind schedule: no sleep, tick immediately.
        assert!(Pacer::lag_at(1.0, Duration::from_secs(1), 1.0).is_none());
        assert!(Pacer::lag_at(0.2, Duration::from_secs(1), 1.0).is_none());
    }

    #[test]
    fn wall_secs_inverts_the_rate() {
        let p = Pacer::new(1000.0);
        assert!((p.wall_secs(5.0) - 0.005).abs() < 1e-12);
        assert!(p.virtual_now() >= 0.0);
        // An instant at/before the pacer's birth maps to virtual 0.0,
        // never negative — arrival stamps must stay in the sim's domain.
        assert_eq!(p.virtual_of(p.start - Duration::from_secs(5)), 0.0);
        assert!(p.virtual_of(Instant::now()) >= 0.0);
    }

    #[test]
    #[should_panic(expected = "pace rate must be positive")]
    fn zero_rate_is_rejected() {
        let _ = Pacer::new(0.0);
    }
}
