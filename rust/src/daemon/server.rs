//! The daemon core (DESIGN.md §10): a blocking `std::net` HTTP server
//! in front of the routed serving simulator.
//!
//! One **pump** thread owns the [`SimRun`] and is the only place the
//! engine steps: it drains submitted jobs from a channel, admits them
//! into the sim (FIFO, gated on free slots), paces ticks against the
//! wall clock, streams freshly decoded tokens back to waiting
//! connections, and — on shutdown — free-runs the drain so in-flight
//! decodes finish while the still-waiting FIFO is shed with structured
//! 503s. A small pool of **worker** threads accepts connections, parses
//! HTTP, validates request bodies and blocks on per-request reply
//! channels; they never touch the engine.
//!
//! The sim's routed mode wants every request at `start_routed` time
//! (dense ids, non-empty prompts), but live prompts are unknown at
//! startup — so the daemon pre-allocates `max_requests` one-token
//! placeholders and rewrites each with the real body
//! ([`SimRun::set_request`]) right before [`SimRun::push_arrival`].
//! Admission order assigns dense ids, so the final report needs no
//! renumbering and `daemon.json` is a well-formed `bench.json`.

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, ErrorKind};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::serve::{paged_context_tokens, resolve_clock, ServeParams, ServeReport};
use crate::coordinator::sim::{Request, Scheduler, SimLoop, SimRun, TickStatus};
use crate::gguf::ModelFile;
use crate::graph::{Engine, KvLayout, KV_BLOCK_TOKENS};
use crate::kernel::BackendKind;
use crate::metrics::{self, Outcome, RequestRecord};
use crate::model::{ByteTokenizer, ModelWeights};
use crate::util::json::{self, Json};

use super::codec::{error_body, Codec, CompletionRequest, CompletionResponse, JsonCodec};
use super::dashboard::DASHBOARD_HTML;
use super::http::{read_request, write_response, ChunkedWriter, HttpRequest, Limits};
use super::pacer::Pacer;
use super::{DaemonParams, DaemonStats};
use crate::util::stats::Summary;

/// How much of the per-step series `/metrics` carries (the full series
/// still lands in `daemon.json`).
const SERIES_TAIL: usize = 256;
/// Most per-request lines `/metrics` retains (oldest dropped first).
const REQUEST_LINES_CAP: usize = 1024;
/// Longest a worker blocks waiting for the pump before answering 500.
const REPLY_TIMEOUT: Duration = Duration::from_secs(600);
/// Longest single pacing nap — the pump re-drains the job channel at
/// least this often even when far ahead of schedule.
const PACE_SLICE: Duration = Duration::from_millis(5);
/// Report files the dashboard may fetch from `report_dir` — an exact
/// whitelist, so the file route cannot traverse anywhere.
const REPORT_FILES: [&str; 4] = ["bench.json", "fleet.json", "cluster.json", "daemon.json"];

/// One validated completion submitted by a worker to the pump.
struct Job {
    prompt: Vec<u32>,
    target_out: usize,
    stream: bool,
    /// Wall instant the HTTP request finished parsing — the measured
    /// TTFT epoch and the virtual arrival stamp.
    submitted: Instant,
    reply: Sender<Reply>,
}

/// What the pump sends back on a job's reply channel.
enum Reply {
    /// Turned away before admission (429 queue-full, 503 draining /
    /// budget-exhausted / shed).
    Rejected {
        status: u16,
        code: &'static str,
        message: String,
        retry_after: Option<u64>,
    },
    /// One freshly decoded token (streaming jobs only).
    Token { index: usize, token: u32 },
    /// The request retired; everything a response needs.
    Done(Box<Done>),
}

struct Done {
    id: usize,
    tokens: Vec<u32>,
    prompt_tokens: usize,
    predicted_ttft_secs: f64,
    predicted_tpot_secs: f64,
    measured_ttft_secs: f64,
    measured_tpot_secs: f64,
}

/// Pump-side state of one admitted request.
struct Track {
    reply: Sender<Reply>,
    prompt_len: usize,
    target: usize,
    stream: bool,
    submit_wall: Instant,
    first_token_wall: Option<Instant>,
    /// Decoded tokens already pushed to the reply channel.
    sent: usize,
}

/// Live metrics shared between the pump (writer) and workers (readers
/// serving `/metrics` and `DaemonHandle::stats`).
struct Hub {
    started: Instant,
    offered: usize,
    served: usize,
    shed: usize,
    rejected: usize,
    active: usize,
    queued: usize,
    requests: VecDeque<Json>,
    cross_sum: f64,
    cross_n: usize,
    measured_ttft: Vec<f64>,
    measured_tpot: Vec<f64>,
    series_t: Vec<f64>,
    series_queue: Vec<usize>,
    series_mbu: Vec<f64>,
}

impl Hub {
    fn new() -> Self {
        Self {
            started: Instant::now(),
            offered: 0,
            served: 0,
            shed: 0,
            rejected: 0,
            active: 0,
            queued: 0,
            requests: VecDeque::new(),
            cross_sum: 0.0,
            cross_n: 0,
            measured_ttft: Vec::new(),
            measured_tpot: Vec::new(),
            series_t: Vec::new(),
            series_queue: Vec::new(),
            series_mbu: Vec::new(),
        }
    }
}

/// State every thread shares.
struct Shared {
    codec: JsonCodec,
    limits: Limits,
    jobs: Mutex<Sender<Job>>,
    hub: Mutex<Hub>,
    /// Drain requested (SIGINT, `POST /admin/shutdown`, or `join`).
    stop: AtomicBool,
    /// Pump exited — workers stop accepting and unwind.
    done: AtomicBool,
    vocab: usize,
    /// Largest prompt + max_tokens a request may claim: the model
    /// window, tightened to the device RAM admission charge on
    /// device-priced runs (the budget `resolve_clock` admitted).
    context_cap: usize,
    pool_blocks: Option<usize>,
    /// Model label responses carry (the quant name).
    model: String,
    pace: f64,
    report_dir: PathBuf,
}

impl Shared {
    /// Lock the hub, absorbing poison: the hub holds only counters and
    /// series, so a panicking peer leaves nothing half-written that a
    /// request path could trip over — recovering keeps live connections
    /// alive instead of cascading the panic.
    fn hub(&self) -> std::sync::MutexGuard<'_, Hub> {
        self.hub.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Lock the job sender, absorbing poison like [`Self::hub`]; a dead
    /// pump surfaces as a send error, which callers already map to a
    /// structured 503.
    fn jobs(&self) -> std::sync::MutexGuard<'_, Sender<Job>> {
        self.jobs.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Everything the pump needs beyond the run itself.
struct PumpCfg {
    slots: usize,
    queue_depth: usize,
    max_requests: usize,
    /// Weight bytes one decoded token must stream — the predicted-MBU
    /// numerator (same figure the sim's step series uses).
    param_bytes: u64,
    /// Peak bandwidth MBU is reported against (the sim's convention:
    /// peak for MBU, achievable for pricing).
    mbu_bw: f64,
    resolved: ServeParams,
    backend: String,
    quant: String,
    scheduler_label: String,
}

/// A FIFO entry shed at shutdown — it never reached the sim, so the
/// report synthesizes its [`Outcome::Shed`] record from these stamps.
struct ShedEntry {
    arrival: f64,
    prompt_tokens: usize,
    target: usize,
    t: f64,
}

/// A running daemon. Dropping the handle does NOT stop the daemon —
/// call [`shutdown`](Self::shutdown) (or let SIGINT / `POST
/// /admin/shutdown` do it) and then [`join`](Self::join) for the final
/// report.
pub struct DaemonHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    pump: JoinHandle<Result<ServeReport>>,
    workers: Vec<JoinHandle<()>>,
}

impl DaemonHandle {
    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request a graceful drain: in-flight decodes finish (free-run),
    /// the waiting FIFO is shed with structured 503s, new arrivals are
    /// rejected.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
    }

    /// Has a drain been requested?
    pub fn draining(&self) -> bool {
        self.shared.stop.load(Ordering::SeqCst)
    }

    /// Has the pump finished draining (the report is ready to `join`)?
    pub fn finished(&self) -> bool {
        self.shared.done.load(Ordering::SeqCst)
    }

    /// Snapshot of the wall-clock counters.
    pub fn stats(&self) -> DaemonStats {
        let hub = self.shared.hub();
        DaemonStats {
            offered: hub.offered,
            served: hub.served,
            shed: hub.shed,
            rejected: hub.rejected,
            uptime_secs: hub.started.elapsed().as_secs_f64(),
            measured_ttft: Summary::of_opt(&hub.measured_ttft),
            measured_tpot: Summary::of_opt(&hub.measured_tpot),
            mbu_cross_check: (hub.cross_n > 0).then(|| hub.cross_sum / hub.cross_n as f64),
            pace: self.shared.pace,
        }
    }

    /// Drain (if not already draining) and wait for the final report —
    /// a [`ServeReport`] whose `to_json()` is a well-formed
    /// `bench.json` document (`daemon.json`).
    pub fn join(self) -> Result<ServeReport> {
        self.shared.stop.store(true, Ordering::SeqCst);
        let report = match self.pump.join() {
            Ok(r) => r,
            Err(_) => Err(anyhow!("daemon pump thread panicked")),
        };
        self.shared.done.store(true, Ordering::SeqCst);
        for w in self.workers {
            let _ = w.join();
        }
        report
    }
}

/// Start the daemon: load the model, start the routed sim over
/// `max_requests` placeholders, bind the listener, and spawn the pump +
/// worker threads. Returns once the socket is accepting.
pub fn spawn(mf: &ModelFile, backend: BackendKind, p: DaemonParams) -> Result<DaemonHandle> {
    p.validate()?;
    let sp = p.serve.clone();
    let weights = ModelWeights::load(mf)?;
    let qtype = weights.qtype;
    let quant = qtype.name().to_string();
    let param_bytes = weights.bytes_per_token();
    let engine = Engine::new_batched_layout(weights, backend, sp.slots, KvLayout::default());
    let vocab = engine.config().vocab_size;
    let max_seq = engine.config().max_seq_len;
    // Same clock resolution as `elib serve` — including the 7B-scale
    // RAM-capacity admission gate on device-priced runs.
    let mut clock = resolve_clock(&sp, engine.config(), qtype)?;
    if let Some(t) = &sp.thermal {
        clock = clock.with_thermal(t.tau, t.floor);
    }
    let mbu_bw = clock.peak_bw;
    let mut resolved = sp.clone();
    resolved.peak_bw = clock.eff_bw;
    resolved.peak_flops = clock.eff_flops;
    // Device-priced daemons were admitted at the serve params' paged
    // context charge; live requests must honor that budget.
    let context_cap = if sp.device.is_some() {
        paged_context_tokens(&sp).min(max_seq)
    } else {
        max_seq
    };

    let mut scheduler: Box<dyn Scheduler> = sp.scheduler.build(sp.seed);
    let placeholders: Vec<Request> = (0..p.max_requests)
        .map(|id| Request {
            id,
            arrival: None,
            prompt: vec![0],
            target_out: 1,
            priority: 0,
            session: None,
            slo: None,
        })
        .collect();
    let run = SimLoop::new(engine, clock, false)
        .with_pool_blocks(sp.pool_blocks)
        .with_prefix_share(sp.prefix_share)
        .start_routed(placeholders, scheduler.as_mut())?;

    let listener = TcpListener::bind((p.host.as_str(), p.port))
        .with_context(|| format!("daemon cannot bind {}:{}", p.host, p.port))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;

    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let shared = Arc::new(Shared {
        codec: JsonCodec,
        limits: Limits::default(),
        jobs: Mutex::new(job_tx),
        hub: Mutex::new(Hub::new()),
        stop: AtomicBool::new(false),
        done: AtomicBool::new(false),
        vocab,
        context_cap,
        pool_blocks: sp.pool_blocks,
        model: quant.clone(),
        pace: p.pace,
        report_dir: p.report_dir.clone(),
    });

    let cfg = PumpCfg {
        slots: sp.slots,
        queue_depth: p.queue_depth,
        max_requests: p.max_requests,
        param_bytes,
        mbu_bw,
        resolved,
        backend: backend.label(),
        quant,
        scheduler_label: sp.scheduler.label().to_string(),
    };
    let pacer = Pacer::new(p.pace);
    let pump_shared = Arc::clone(&shared);
    let pump = thread::Builder::new()
        .name("elib-daemon-pump".into())
        .spawn(move || {
            let result = pump_loop(run, scheduler, job_rx, pacer, &pump_shared, cfg);
            pump_shared.done.store(true, Ordering::SeqCst);
            result
        })
        .context("spawning the daemon pump thread")?;

    let mut workers = Vec::with_capacity(p.workers);
    for i in 0..p.workers {
        let l = listener.try_clone().context("cloning the daemon listener")?;
        let s = Arc::clone(&shared);
        workers.push(
            thread::Builder::new()
                .name(format!("elib-daemon-worker-{i}"))
                .spawn(move || worker_loop(l, &s))
                .context("spawning a daemon worker thread")?,
        );
    }

    Ok(DaemonHandle { addr, shared, pump, workers })
}

// ---------------------------------------------------------------------------
// Pump: the only thread that touches the engine.
// ---------------------------------------------------------------------------

fn pump_loop(
    mut run: SimRun,
    mut scheduler: Box<dyn Scheduler>,
    jobs: Receiver<Job>,
    pacer: Pacer,
    shared: &Shared,
    cfg: PumpCfg,
) -> Result<ServeReport> {
    let mut waiting: VecDeque<(Job, f64)> = VecDeque::new();
    let mut tracked: BTreeMap<usize, Track> = BTreeMap::new();
    let mut shed_log: Vec<ShedEntry> = Vec::new();
    let mut next_id = 0usize;
    let mut draining = false;

    loop {
        // 1. Intake: drain every job the workers submitted since the
        //    last iteration. Door rejections (429/503) happen here, so
        //    a rejected request never consumes a sim id.
        loop {
            let job = match jobs.try_recv() {
                Ok(j) => j,
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            };
            if draining {
                let _ = job.reply.send(Reply::Rejected {
                    status: 503,
                    code: "shutting_down",
                    message: "daemon is draining; no new work accepted".into(),
                    retry_after: None,
                });
                shared.hub().rejected += 1;
            } else if next_id + waiting.len() >= cfg.max_requests {
                let _ = job.reply.send(Reply::Rejected {
                    status: 503,
                    code: "request_budget_exhausted",
                    message: format!(
                        "daemon lifetime budget of {} requests is spent; restart to reset",
                        cfg.max_requests
                    ),
                    retry_after: None,
                });
                shared.hub().rejected += 1;
            } else if waiting.len() >= cfg.queue_depth && run.load() >= cfg.slots {
                let retry = retry_after_secs(&run, &waiting, &tracked, cfg.slots, &pacer);
                let _ = job.reply.send(Reply::Rejected {
                    status: 429,
                    code: "queue_full",
                    message: format!(
                        "{} request(s) waiting and every slot busy; retry after {retry}s",
                        waiting.len()
                    ),
                    retry_after: Some(retry),
                });
                shared.hub().rejected += 1;
            } else {
                let arrival = pacer.virtual_of(job.submitted);
                waiting.push_back((job, arrival));
                shared.hub().offered += 1;
            }
        }

        // 2. Drain transition: shed the FIFO once with structured
        //    errors; in-flight work keeps decoding (free-run below).
        if !draining && shared.stop.load(Ordering::SeqCst) {
            draining = true;
            let shed_t = run.now().max(pacer.virtual_now());
            let mut shed_n = 0usize;
            while let Some((job, arrival)) = waiting.pop_front() {
                let _ = job.reply.send(Reply::Rejected {
                    status: 503,
                    code: "shutting_down",
                    message: "daemon is draining; queued request shed".into(),
                    retry_after: None,
                });
                shed_log.push(ShedEntry {
                    arrival: arrival.min(shed_t),
                    prompt_tokens: job.prompt.len(),
                    target: job.target_out,
                    t: shed_t,
                });
                shed_n += 1;
            }
            shared.hub().shed += shed_n;
        }

        // 3. Admission: FIFO into free slots. Ids are dense in
        //    admission order — the report needs no renumbering.
        while run.load() < cfg.slots {
            let Some((job, arrival)) = waiting.pop_front() else { break };
            let id = next_id;
            next_id += 1;
            run.set_request(id, job.prompt.clone(), job.target_out)?;
            run.push_arrival(id, arrival)?;
            tracked.insert(
                id,
                Track {
                    reply: job.reply,
                    prompt_len: job.prompt.len(),
                    target: job.target_out,
                    stream: job.stream,
                    submit_wall: job.submitted,
                    first_token_wall: None,
                    sent: 0,
                },
            );
        }

        if draining && waiting.is_empty() && run.drained() {
            break;
        }

        // 4. Pacing: sleep while the sim is ahead of the wall clock —
        //    in short slices, so intake stays responsive. The drain
        //    free-runs (wall time owes the sim nothing on the way out).
        if !draining {
            if let Some(lag) = pacer.lag(run.now()) {
                thread::sleep(lag.min(PACE_SLICE));
                if lag > PACE_SLICE {
                    sync_hub(shared, &run, &tracked, &waiting);
                    continue;
                }
            }
        }

        // 5. One sim step.
        let status = run.tick_routed(scheduler.as_mut())?;

        // 6. Delivery: push freshly decoded tokens to streaming jobs,
        //    retire completed ones. Retirement is polled via
        //    `record(id)` — not `take_finishes` — because SLO shed /
        //    preempt paths write records without touching the finish
        //    buffer, and the daemon must never hang a connection.
        let now_wall = Instant::now();
        let mut finished: Vec<usize> = Vec::new();
        for (&id, t) in tracked.iter_mut() {
            let seq = run.sequence(id);
            let decoded = seq.len().saturating_sub(t.prompt_len);
            if decoded > t.sent {
                if t.first_token_wall.is_none() {
                    t.first_token_wall = Some(now_wall);
                }
                if t.stream {
                    for i in t.sent..decoded {
                        let _ = t.reply.send(Reply::Token { index: i, token: seq[t.prompt_len + i] });
                    }
                }
                t.sent = decoded;
            }
            if run.record(id).is_some() {
                finished.push(id);
            }
        }
        for id in finished {
            // Both lookups held a moment ago; a miss here means the sim
            // dropped the id mid-tick — skip the record rather than
            // panic the pump (which would strand every live connection).
            let Some(t) = tracked.remove(&id) else { continue };
            let Some(rec) = run.record(id).cloned() else { continue };
            retire(&rec, &t, id, now_wall, &run, &pacer, shared, &cfg);
        }
        let _ = run.take_finishes();

        sync_hub(shared, &run, &tracked, &waiting);

        // 7. Idle nap: nothing running, nothing waiting, not draining —
        //    don't spin against the job channel.
        if status == TickStatus::Idle && waiting.is_empty() && tracked.is_empty() && !draining {
            thread::sleep(Duration::from_millis(2));
        }
    }

    build_report(run, next_id, shed_log, cfg)
}

/// Finish one retired request: measured-vs-predicted latencies, the MBU
/// cross-check, the `/metrics` request line, and the `Done` reply.
#[allow(clippy::too_many_arguments)]
fn retire(
    rec: &RequestRecord,
    t: &Track,
    id: usize,
    now_wall: Instant,
    run: &SimRun,
    pacer: &Pacer,
    shared: &Shared,
    cfg: &PumpCfg,
) {
    let first = t.first_token_wall.unwrap_or(now_wall);
    let measured_ttft = first.saturating_duration_since(t.submit_wall).as_secs_f64();
    let measured_tpot = if rec.output_tokens > 1 {
        now_wall.saturating_duration_since(first).as_secs_f64() / (rec.output_tokens - 1) as f64
    } else {
        0.0
    };
    // The cross-check compares like with like: measured wall TPOT is
    // mapped into virtual seconds at the pace rate, so at pace 1.0 with
    // perfect pricing the ratio is 1 and measured MBU equals predicted.
    let predicted_mbu = metrics::mbu(cfg.param_bytes, 0, rec.tpot(), cfg.mbu_bw);
    let cross = metrics::mbu_cross_check(rec.tpot(), measured_tpot * pacer.rate(), predicted_mbu);
    let tokens: Vec<u32> = run.sequence(id)[t.prompt_len..].to_vec();

    let mut hub = shared.hub();
    hub.served += 1;
    hub.measured_ttft.push(measured_ttft);
    if rec.output_tokens > 1 {
        hub.measured_tpot.push(measured_tpot);
    }
    if let Some(c) = cross {
        hub.cross_sum += c;
        hub.cross_n += 1;
    }
    let mut line = rec.to_json();
    if let Json::Obj(m) = &mut line {
        m.insert("kind".into(), Json::Str("request".into()));
        m.insert("measured_ttft_secs".into(), Json::Num(measured_ttft));
        m.insert("measured_tpot_secs".into(), Json::Num(measured_tpot));
        if let Some(c) = cross {
            m.insert("mbu_cross_check".into(), Json::Num(c));
        }
    }
    if hub.requests.len() >= REQUEST_LINES_CAP {
        hub.requests.pop_front();
    }
    hub.requests.push_back(line);
    drop(hub);

    let _ = t.reply.send(Reply::Done(Box::new(Done {
        id,
        tokens,
        prompt_tokens: rec.prompt_tokens,
        predicted_ttft_secs: rec.ttft(),
        predicted_tpot_secs: rec.tpot(),
        measured_ttft_secs: measured_ttft,
        measured_tpot_secs: measured_tpot,
    })));
}

/// Honest 429 hint: time to chew through the backlog at the observed
/// virtual per-token cost, mapped to wall seconds. Clamped to [1, 600].
fn retry_after_secs(
    run: &SimRun,
    waiting: &VecDeque<(Job, f64)>,
    tracked: &BTreeMap<usize, Track>,
    slots: usize,
    pacer: &Pacer,
) -> u64 {
    let est = if run.processed_tokens() > 0 {
        run.busy_secs() / run.processed_tokens() as f64
    } else {
        0.01 // nothing processed yet: a token-scale placeholder
    };
    let backlog: usize = tracked.values().map(|t| t.prompt_len + t.target - t.sent).sum::<usize>()
        + waiting.iter().map(|(j, _)| j.prompt.len() + j.target_out).sum::<usize>();
    let virt = est * backlog as f64 / slots.max(1) as f64;
    (pacer.wall_secs(virt).ceil() as u64).clamp(1, 600)
}

/// Copy the live gauges and series tails into the hub.
fn sync_hub(
    shared: &Shared,
    run: &SimRun,
    tracked: &BTreeMap<usize, Track>,
    waiting: &VecDeque<(Job, f64)>,
) {
    let mut hub = shared.hub();
    hub.active = tracked.len();
    hub.queued = waiting.len();
    let from = run.step_t().len().saturating_sub(SERIES_TAIL);
    hub.series_t = run.step_t()[from..].to_vec();
    hub.series_queue = run.step_queue()[from..].to_vec();
    hub.series_mbu = run.step_mbu()[from..].to_vec();
}

/// Assemble `daemon.json`'s report: the admitted requests' records in
/// id order, then a synthesized [`Outcome::Shed`] record per FIFO entry
/// shed at shutdown — so `served + shed = offered` is visible in the
/// document and the records count equals every request the daemon
/// accepted.
fn build_report(
    run: SimRun,
    next_id: usize,
    shed_log: Vec<ShedEntry>,
    cfg: PumpCfg,
) -> Result<ServeReport> {
    let out = run.finish_routed();
    let mut records: Vec<RequestRecord> = Vec::with_capacity(next_id + shed_log.len());
    for (id, r) in out.records.into_iter().enumerate().take(next_id) {
        records.push(r.ok_or_else(|| anyhow!("admitted request {id} has no record after drain"))?);
    }
    let mut sequences: Vec<Vec<u32>> = out.sequences;
    sequences.truncate(next_id);
    for (k, e) in shed_log.iter().enumerate() {
        records.push(RequestRecord {
            id: next_id + k,
            arrival: e.arrival,
            admit: e.t,
            first_token: e.t,
            finish: e.t,
            prompt_tokens: e.prompt_tokens,
            output_tokens: 0,
            slo: None,
            outcome: Outcome::Shed,
            target_tokens: e.target,
        });
        sequences.push(Vec::new());
    }
    let mut params = cfg.resolved;
    params.num_requests = records.len().max(1);
    Ok(ServeReport {
        params,
        backend: cfg.backend,
        quant: cfg.quant,
        workload: "daemon".into(),
        scheduler: cfg.scheduler_label,
        reuse: out.reuse,
        records,
        sequences,
        captured_logits: Vec::new(),
        step_t: out.step_t,
        step_queue: out.step_queue,
        step_active: out.step_active,
        step_mbu: out.step_mbu,
        output_tokens: out.output_tokens,
        makespan_secs: out.makespan_secs,
        deferred_admissions: out.deferred_admissions,
        shed_requests: out.shed_requests + shed_log.len(),
        preempted_requests: out.preempted_requests,
        kv_pool: out.kv_pool,
    })
}

// ---------------------------------------------------------------------------
// Workers: accept, parse, validate, block on the reply channel.
// ---------------------------------------------------------------------------

fn worker_loop(listener: TcpListener, shared: &Shared) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => handle_conn(stream, shared),
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if shared.done.load(Ordering::SeqCst) {
                    return;
                }
                thread::sleep(Duration::from_millis(3));
            }
            Err(_) => {
                if shared.done.load(Ordering::SeqCst) {
                    return;
                }
                thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// One connection's keep-alive loop. The read timeout doubles as the
/// shutdown poll interval and as slow-client protection.
fn handle_conn(stream: TcpStream, shared: &Shared) {
    // The listener is non-blocking (accept poll); the accepted stream
    // must not be.
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let _ = stream.set_nodelay(true);
    let Ok(clone) = stream.try_clone() else { return };
    let mut reader = BufReader::new(clone);
    let mut writer = stream;
    loop {
        // Wait for the next request's first byte (or clean EOF) before
        // invoking the parser, so idle keep-alive gaps are not 400s.
        match reader.fill_buf() {
            Ok(b) if b.is_empty() => return,
            Ok(_) => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if shared.done.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        match read_request(&mut reader, &shared.limits) {
            Ok(None) => return,
            Ok(Some(req)) => {
                let close = req.wants_close();
                if route(&req, &mut writer, shared).is_err() || close {
                    return;
                }
            }
            Err(he) => {
                // The stream position is unknown after a framing error:
                // answer and close.
                let _ = respond_error(&mut writer, he.status, "bad_request", &he.message, shared);
                return;
            }
        }
    }
}

fn route(req: &HttpRequest, w: &mut TcpStream, shared: &Shared) -> std::io::Result<()> {
    let path = req.target.split('?').next().unwrap_or("");
    match (req.method.as_str(), path) {
        ("GET", "/") | ("GET", "/index.html") => {
            write_response(w, 200, "text/html; charset=utf-8", &[], DASHBOARD_HTML.as_bytes())
        }
        ("GET", "/metrics") => {
            let body = metrics_snapshot(shared);
            write_response(w, 200, "application/x-ndjson", &[], body.as_bytes())
        }
        ("GET", "/healthz") => {
            let status = if shared.stop.load(Ordering::SeqCst) { "draining" } else { "ok" };
            let body = json::to_string(&Json::obj(vec![("status", Json::Str(status.into()))]));
            write_response(w, 200, shared.codec.content_type(), &[], body.as_bytes())
        }
        ("POST", "/admin/shutdown") => {
            shared.stop.store(true, Ordering::SeqCst);
            let body = json::to_string(&Json::obj(vec![("status", Json::Str("draining".into()))]));
            write_response(w, 202, shared.codec.content_type(), &[], body.as_bytes())
        }
        ("POST", "/v1/completions") => completions(req, w, shared),
        ("GET", p) if REPORT_FILES.contains(&p.trim_start_matches('/')) => {
            // Exact-name whitelist — no traversal surface.
            match std::fs::read(shared.report_dir.join(p.trim_start_matches('/'))) {
                Ok(bytes) => write_response(w, 200, "application/json", &[], &bytes),
                Err(_) => respond_error(w, 404, "not_found", "no such report beside the daemon", shared),
            }
        }
        _ => respond_error(
            w,
            404,
            "not_found",
            &format!("no route for {} {}", req.method, path),
            shared,
        ),
    }
}

fn respond_error(
    w: &mut TcpStream,
    status: u16,
    code: &str,
    message: &str,
    shared: &Shared,
) -> std::io::Result<()> {
    let body = json::to_string(&error_body(code, message));
    write_response(w, status, shared.codec.content_type(), &[], body.as_bytes())
}

/// `POST /v1/completions`: validate, submit to the pump, answer —
/// unary JSON or a chunked SSE stream.
fn completions(req: &HttpRequest, w: &mut TcpStream, shared: &Shared) -> std::io::Result<()> {
    let creq = match shared
        .codec
        .decode(&mut req.body.as_slice())
        .and_then(|v| CompletionRequest::from_json(&v))
    {
        Ok(c) => c,
        Err(e) => return respond_error(w, 400, "invalid_request", &format!("{e:#}"), shared),
    };
    let toks = match creq.tokens(shared.vocab) {
        Ok(t) => t,
        Err(e) => return respond_error(w, 400, "invalid_prompt", &format!("{e:#}"), shared),
    };
    let need = toks.len() + creq.max_tokens;
    if need > shared.context_cap {
        return respond_error(
            w,
            400,
            "context_overflow",
            &format!("prompt + max_tokens = {need} exceeds the context budget {}", shared.context_cap),
            shared,
        );
    }
    // Mirror of `start_routed`'s pool invariant: a request whose chain
    // cannot fit the block budget would defer forever, so refuse it at
    // the door instead.
    if let Some(budget) = shared.pool_blocks {
        let blocks = need.div_ceil(KV_BLOCK_TOKENS);
        if blocks > budget {
            return respond_error(
                w,
                400,
                "kv_budget_overflow",
                &format!("request needs {blocks} kv block(s) but the pool budget is {budget}"),
                shared,
            );
        }
    }
    let (tx, rx) = mpsc::channel::<Reply>();
    let job = Job {
        prompt: toks,
        target_out: creq.max_tokens,
        stream: creq.stream,
        submitted: Instant::now(),
        reply: tx,
    };
    if shared.jobs().send(job).is_err() {
        return respond_error(w, 503, "shutting_down", "daemon loop has exited", shared);
    }
    if creq.stream {
        stream_reply(&rx, w, shared)
    } else {
        unary_reply(&rx, w, shared)
    }
}

fn response_of(d: &Done, shared: &Shared) -> CompletionResponse {
    CompletionResponse {
        id: d.id,
        model: shared.model.clone(),
        text: ByteTokenizer.decode(&d.tokens),
        tokens: d.tokens.clone(),
        prompt_tokens: d.prompt_tokens,
        predicted_ttft_secs: d.predicted_ttft_secs,
        predicted_tpot_secs: d.predicted_tpot_secs,
        measured_ttft_secs: d.measured_ttft_secs,
        measured_tpot_secs: d.measured_tpot_secs,
    }
}

fn rejection_response(
    w: &mut TcpStream,
    status: u16,
    code: &'static str,
    message: &str,
    retry_after: Option<u64>,
    shared: &Shared,
) -> std::io::Result<()> {
    let extra: Vec<(&str, String)> =
        retry_after.map(|s| ("Retry-After", s.to_string())).into_iter().collect();
    let body = json::to_string(&error_body(code, message));
    write_response(w, status, shared.codec.content_type(), &extra, body.as_bytes())
}

fn unary_reply(rx: &Receiver<Reply>, w: &mut TcpStream, shared: &Shared) -> std::io::Result<()> {
    loop {
        match rx.recv_timeout(REPLY_TIMEOUT) {
            // Unary responses carry the full token list in one body.
            Ok(Reply::Token { .. }) => continue,
            Ok(Reply::Rejected { status, code, message, retry_after }) => {
                return rejection_response(w, status, code, &message, retry_after, shared);
            }
            Ok(Reply::Done(d)) => {
                let body = json::to_string(&response_of(&d, shared).to_json());
                return write_response(w, 200, shared.codec.content_type(), &[], body.as_bytes());
            }
            Err(RecvTimeoutError::Timeout) => {
                return respond_error(w, 500, "timeout", "request timed out in the daemon", shared);
            }
            Err(RecvTimeoutError::Disconnected) => {
                return respond_error(w, 500, "internal", "daemon loop dropped the request", shared);
            }
        }
    }
}

/// Streaming path: the first reply decides the framing — a rejection is
/// a plain status response; anything else opens the SSE stream. Events
/// are `data: {json}\n\n`, the terminal event is the same response
/// object the unary path returns, then `data: [DONE]\n\n`.
fn stream_reply(rx: &Receiver<Reply>, w: &mut TcpStream, shared: &Shared) -> std::io::Result<()> {
    let first = match rx.recv_timeout(REPLY_TIMEOUT) {
        Ok(r) => r,
        Err(RecvTimeoutError::Timeout) => {
            return respond_error(w, 500, "timeout", "request timed out in the daemon", shared);
        }
        Err(RecvTimeoutError::Disconnected) => {
            return respond_error(w, 500, "internal", "daemon loop dropped the request", shared);
        }
    };
    if let Reply::Rejected { status, code, message, retry_after } = first {
        return rejection_response(w, status, code, &message, retry_after, shared);
    }
    let mut cw = ChunkedWriter::new(w, 200, "text/event-stream")?;
    let mut pending = Some(first);
    loop {
        let reply = match pending.take() {
            Some(r) => r,
            None => match rx.recv_timeout(REPLY_TIMEOUT) {
                Ok(r) => r,
                Err(_) => {
                    cw.chunk(
                        b"data: {\"error\":{\"code\":\"internal\",\"message\":\"stream interrupted\"}}\n\n",
                    )?;
                    cw.chunk(b"data: [DONE]\n\n")?;
                    return cw.finish();
                }
            },
        };
        match reply {
            Reply::Token { index, token } => {
                let ev = Json::obj(vec![
                    ("index", Json::Num(index as f64)),
                    ("token", Json::Num(token as f64)),
                    ("text", Json::Str(ByteTokenizer.decode(&[token]))),
                ]);
                cw.chunk(format!("data: {}\n\n", json::to_string(&ev)).as_bytes())?;
            }
            Reply::Done(d) => {
                let resp = response_of(&d, shared);
                cw.chunk(format!("data: {}\n\n", json::to_string(&resp.to_json())).as_bytes())?;
                cw.chunk(b"data: [DONE]\n\n")?;
                return cw.finish();
            }
            Reply::Rejected { code, message, .. } => {
                // Cannot change the status line mid-stream; surface as
                // an SSE error event instead.
                let ev = error_body(code, &message);
                cw.chunk(format!("data: {}\n\n", json::to_string(&ev)).as_bytes())?;
                cw.chunk(b"data: [DONE]\n\n")?;
                return cw.finish();
            }
        }
    }
}

/// The `/metrics` snapshot: JSON lines — one `daemon` aggregate line,
/// one `request` line per retired request (capped, oldest dropped), one
/// `series` line with the step-series tails.
fn metrics_snapshot(shared: &Shared) -> String {
    let hub = shared.hub();
    let head = Json::obj(vec![
        ("kind", Json::Str("daemon".into())),
        ("codec", Json::Str(shared.codec.name().into())),
        ("offered", Json::Num(hub.offered as f64)),
        ("served", Json::Num(hub.served as f64)),
        ("shed", Json::Num(hub.shed as f64)),
        ("rejected", Json::Num(hub.rejected as f64)),
        ("active", Json::Num(hub.active as f64)),
        ("queued", Json::Num(hub.queued as f64)),
        ("uptime_secs", Json::Num(hub.started.elapsed().as_secs_f64())),
        ("pace", Json::Num(shared.pace)),
        ("draining", Json::Bool(shared.stop.load(Ordering::SeqCst))),
        (
            "mbu_cross_check",
            if hub.cross_n > 0 { Json::Num(hub.cross_sum / hub.cross_n as f64) } else { Json::Null },
        ),
    ]);
    let mut s = json::to_string(&head);
    s.push('\n');
    for line in &hub.requests {
        s.push_str(&json::to_string(line));
        s.push('\n');
    }
    let series = Json::obj(vec![
        ("kind", Json::Str("series".into())),
        ("t", Json::Arr(hub.series_t.iter().map(|&v| Json::Num(v)).collect())),
        (
            "queue_depth",
            Json::Arr(hub.series_queue.iter().map(|&v| Json::Num(v as f64)).collect()),
        ),
        ("mbu", Json::Arr(hub.series_mbu.iter().map(|&v| Json::Num(v)).collect())),
    ]);
    s.push_str(&json::to_string(&series));
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serve::run_serve;
    use crate::coordinator::{compare_bench, ServeParams};
    use crate::model::testutil::random_model_file;
    use crate::quant::QuantType;
    use std::io::{Read, Write};

    fn daemon_params(serve: ServeParams) -> DaemonParams {
        DaemonParams {
            host: "127.0.0.1".into(),
            port: 0, // ephemeral
            workers: 2,
            queue_depth: 8,
            max_requests: 64,
            pace: 1e6, // tests free-run unless they override
            report_dir: PathBuf::from("."),
            serve,
        }
    }

    /// One exchange over a fresh connection (the request must carry
    /// `Connection: close`): returns (status, raw headers, body),
    /// de-chunking streamed responses.
    fn http(addr: SocketAddr, request: &str) -> (u16, String, Vec<u8>) {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(request.as_bytes()).expect("send");
        let mut raw = Vec::new();
        s.read_to_end(&mut raw).expect("read");
        parse_response(&raw)
    }

    fn parse_response(raw: &[u8]) -> (u16, String, Vec<u8>) {
        let pos = raw.windows(4).position(|w| w == b"\r\n\r\n").expect("header terminator") + 4;
        let head = String::from_utf8_lossy(&raw[..pos]).to_string();
        let status: u16 = head.split(' ').nth(1).expect("status").parse().expect("status code");
        let mut body = raw[pos..].to_vec();
        if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
            body = dechunk(&body);
        }
        (status, head, body)
    }

    fn dechunk(mut b: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        loop {
            let nl = b.windows(2).position(|w| w == b"\r\n").expect("chunk size line");
            let size =
                usize::from_str_radix(std::str::from_utf8(&b[..nl]).unwrap().trim(), 16).unwrap();
            b = &b[nl + 2..];
            if size == 0 {
                break;
            }
            out.extend_from_slice(&b[..size]);
            b = &b[size + 2..];
        }
        out
    }

    fn sse_events(body: &[u8]) -> Vec<String> {
        String::from_utf8_lossy(body)
            .split("\n\n")
            .filter_map(|e| e.strip_prefix("data: ").map(str::to_string))
            .collect()
    }

    fn post(addr: SocketAddr, path: &str, body: &Json) -> (u16, String, Vec<u8>) {
        let body = json::to_string(body);
        http(
            addr,
            &format!(
                "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
                 Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
                body.len(),
                body
            ),
        )
    }

    fn completion_body(prompt: &[u32], max_tokens: usize, stream: bool) -> Json {
        Json::obj(vec![
            ("prompt_tokens", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
            ("max_tokens", Json::Num(max_tokens as f64)),
            ("stream", Json::Bool(stream)),
        ])
    }

    /// Open a streaming completion on its own connection and block
    /// until the first SSE event arrives — proof the request holds a
    /// slot. Returns the connection and the bytes read so far.
    fn open_stream(addr: SocketAddr, prompt: &[u32], max_tokens: usize) -> (TcpStream, Vec<u8>) {
        let body = json::to_string(&completion_body(prompt, max_tokens, true));
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(
            format!(
                "POST /v1/completions HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
                 Content-Length: {}\r\n\r\n{}",
                body.len(),
                body
            )
            .as_bytes(),
        )
        .expect("send");
        let mut got = Vec::new();
        let mut buf = [0u8; 1024];
        while !got.windows(6).any(|w| w == b"data: ") {
            let n = s.read(&mut buf).expect("stream read");
            assert!(n > 0, "stream closed before the first token");
            got.extend_from_slice(&buf[..n]);
        }
        (s, got)
    }

    /// Acceptance gate: the daemon serves the exact token streams a
    /// virtual-time `elib serve` run of the same seed produces, for
    /// both the unary and the SSE path.
    #[test]
    fn daemon_tokens_match_the_virtual_time_serve_run() {
        let mf = random_model_file(QuantType::Q8_0, 29);
        let p = ServeParams::builder()
            .num_requests(6)
            .slots(2)
            .prompt_len(2, 5)
            .output_len(2, 6)
            .seed(11)
            .build()
            .unwrap();
        let solo = run_serve(&mf, BackendKind::Naive, &p).unwrap();
        let handle = spawn(&mf, BackendKind::Naive, daemon_params(p)).unwrap();
        let addr = handle.addr();
        for (id, rec) in solo.records.iter().enumerate() {
            let prompt = &solo.sequences[id][..rec.prompt_tokens];
            let want = &solo.sequences[id][rec.prompt_tokens..];
            let stream = id % 2 == 1;
            let (status, _head, body) =
                post(addr, "/v1/completions", &completion_body(prompt, rec.target_tokens, stream));
            assert_eq!(status, 200, "request {id}: {}", String::from_utf8_lossy(&body));
            let resp = if stream {
                let events = sse_events(&body);
                assert_eq!(events.last().map(String::as_str), Some("[DONE]"), "request {id}");
                // Per-token events, then the terminal response object.
                let toks: Vec<u32> = events[..events.len() - 2]
                    .iter()
                    .map(|e| json::parse(e).unwrap().req_usize("token").unwrap() as u32)
                    .collect();
                assert_eq!(toks, want, "request {id} streamed tokens drift");
                CompletionResponse::from_json(&json::parse(&events[events.len() - 2]).unwrap())
                    .unwrap()
            } else {
                CompletionResponse::from_json(
                    &json::parse(std::str::from_utf8(&body).unwrap()).unwrap(),
                )
                .unwrap()
            };
            assert_eq!(resp.tokens, want, "request {id} token drift vs elib serve");
            assert_eq!(resp.prompt_tokens, rec.prompt_tokens, "request {id}");
            assert!(resp.measured_ttft_secs >= 0.0 && resp.predicted_ttft_secs >= 0.0);
        }
        // /metrics reflects the finished run before shutdown.
        let (status, _h, body) =
            http(addr, "GET /metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert_eq!(status, 200);
        let first_line = std::str::from_utf8(&body).unwrap().lines().next().unwrap().to_string();
        let agg = json::parse(&first_line).unwrap();
        assert_eq!(agg.req_usize("served").unwrap(), 6);
        assert_eq!(agg.get("kind").and_then(Json::as_str), Some("daemon"));
        // Dashboard + health routes answer.
        let (status, _h, page) =
            http(addr, "GET / HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert_eq!(status, 200);
        assert!(String::from_utf8_lossy(&page).contains("elib daemon"));
        let (status, _h, _b) =
            http(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert_eq!(status, 200);

        let rep = handle.join().unwrap();
        assert_eq!(rep.records.len(), 6);
        assert!(rep.records.iter().all(|r| r.outcome == Outcome::Served));
        assert_eq!(rep.output_tokens, solo.output_tokens, "aggregate token drift");
        assert_eq!(rep.tokens_fnv(), solo.tokens_fnv(), "token fingerprint drift");
    }

    /// Acceptance gate: saturating the slots and the waiting room
    /// yields 429 with an honest `Retry-After`, and the rejected
    /// request never pollutes the records.
    #[test]
    fn saturated_slots_reject_with_retry_after() {
        let mf = random_model_file(QuantType::Q8_0, 31);
        let p = ServeParams::builder().slots(1).prompt_len(2, 4).output_len(2, 4).build().unwrap();
        let mut dp = daemon_params(p);
        dp.queue_depth = 0; // no waiting room: busy slot => 429
        dp.pace = 0.05; // slow enough that the decode is provably in flight
        let handle = spawn(&mf, BackendKind::Naive, dp).unwrap();
        let addr = handle.addr();

        let (mut s1, mut got) = open_stream(addr, &[1, 2], 64);

        let (status, head, body) = post(addr, "/v1/completions", &completion_body(&[3], 2, false));
        assert_eq!(status, 429, "{}", String::from_utf8_lossy(&body));
        let retry: u64 = head
            .lines()
            .find_map(|l| l.strip_prefix("Retry-After: "))
            .expect("Retry-After header")
            .trim()
            .parse()
            .unwrap();
        assert!((1..=600).contains(&retry), "retry hint {retry} out of range");
        let err = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(err.at(&["error", "code"]).unwrap().as_str(), Some("queue_full"));

        // Graceful drain: the in-flight stream runs to [DONE] free-run.
        let (status, _h, _b) = post(addr, "/admin/shutdown", &Json::obj(vec![]));
        assert_eq!(status, 202);
        let mut rest = Vec::new();
        s1.read_to_end(&mut rest).unwrap();
        got.extend_from_slice(&rest);
        assert!(
            got.windows(14).any(|w| w == b"data: [DONE]\n\n"),
            "stream must finish through the drain"
        );
        let stats = handle.stats();
        assert_eq!((stats.offered, stats.served, stats.rejected), (1, 1, 1));
        let rep = handle.join().unwrap();
        assert_eq!(rep.records.len(), 1, "the rejected request must not enter the records");
        assert_eq!(rep.records[0].output_tokens, 64);
    }

    /// Acceptance gate: shutdown drains the in-flight decode, sheds the
    /// FIFO with structured 503s, and the report conserves requests —
    /// served + shed = offered — while `daemon.json` stays a valid
    /// bench document under `compare_bench`.
    #[test]
    fn shutdown_drains_in_flight_and_sheds_the_queue() {
        let mf = random_model_file(QuantType::Q8_0, 37);
        let p = ServeParams::builder().slots(1).prompt_len(2, 4).output_len(2, 4).build().unwrap();
        let mut dp = daemon_params(p);
        dp.queue_depth = 8;
        // ~9 ms virtual per step at 0.05 pace = ~180 ms of wall per
        // token: the first token lands fast, but request 1's 64-token
        // decode cannot finish before the drain lands.
        dp.pace = 0.05;
        let handle = spawn(&mf, BackendKind::Naive, dp).unwrap();
        let addr = handle.addr();

        let (mut s1, mut got) = open_stream(addr, &[1, 2], 64);
        let joins: Vec<_> = (0..3)
            .map(|i| {
                thread::spawn(move || {
                    post(addr, "/v1/completions", &completion_body(&[i + 1], 4, false))
                })
            })
            .collect();
        // Wait until all four requests are accepted, then drain.
        let deadline = Instant::now() + Duration::from_secs(10);
        while handle.stats().offered < 4 {
            assert!(Instant::now() < deadline, "queued posts never landed");
            thread::sleep(Duration::from_millis(5));
        }
        let (status, _h, _b) = post(addr, "/admin/shutdown", &Json::obj(vec![]));
        assert_eq!(status, 202);

        for j in joins {
            let (status, _h, body) = j.join().unwrap();
            assert_eq!(status, 503, "{}", String::from_utf8_lossy(&body));
            let err = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            assert_eq!(err.at(&["error", "code"]).unwrap().as_str(), Some("shutting_down"));
        }
        let mut rest = Vec::new();
        s1.read_to_end(&mut rest).unwrap();
        got.extend_from_slice(&rest);
        assert!(got.windows(14).any(|w| w == b"data: [DONE]\n\n"), "in-flight decode must drain");

        let rep = handle.join().unwrap();
        // Conservation: every accepted request has exactly one record.
        assert_eq!(rep.records.len(), 4, "served + shed must equal offered");
        let served = rep.records.iter().filter(|r| r.outcome == Outcome::Served).count();
        let shed = rep.records.iter().filter(|r| r.outcome == Outcome::Shed).count();
        assert_eq!((served, shed), (1, 3));
        assert_eq!(rep.shed_requests, 3);
        for r in &rep.records {
            assert!(r.finish >= r.arrival, "record {} lifecycle out of order", r.id);
        }
        // daemon.json round-trips through the bench schema and passes
        // the baseline gate against itself.
        let doc = rep.to_json();
        let parsed = json::parse(&json::to_string(&doc)).unwrap();
        assert_eq!(parsed.at(&["aggregate", "num_requests"]).unwrap().as_usize(), Some(4));
        assert!(compare_bench(&parsed, &doc, 1.0).is_pass());
    }
}
