//! Edge sweep — the paper's full evaluation grid (§5): three devices ×
//! three accelerators × five quantized models, printing Table 6 and all
//! figure series. This is `elib bench` as a library-API example.
//!
//!     make artifacts && cargo run --release --example edge_sweep

use anyhow::Result;

use elib::coordinator::{Elib, ElibConfig};
use elib::report;

fn main() -> Result<()> {
    let mut cfg = ElibConfig::default();
    cfg.out_dir = "target/elib-out/edge_sweep".into();
    // Keep the host measurement light; the simulated grid is exhaustive.
    cfg.bench.gen_tokens = 24;
    cfg.bench.ppl_tokens = 256;

    let (rep, json_path) = Elib::new(cfg).run()?;
    println!("\n{}", report::full_report(&rep));
    println!("{} Table-6 rows, {} skipped cells", rep.records.len(), rep.skipped.len());
    println!("json report: {}", json_path.display());

    // Sanity: the paper's three headline relationships.
    let ratios = report::summary_ratios(&rep.records);
    for r in &ratios {
        assert!(
            r.q4_vs_q8_cpu > 1.0,
            "{}: q4_0 must out-throughput q8_0 on CPU",
            r.device
        );
        assert!(
            r.gpu_vs_cpu_mean > 1.0,
            "{}: GPU must out-throughput CPU on average",
            r.device
        );
    }
    println!("\nheadline relationships hold on all devices ✓");
    Ok(())
}
