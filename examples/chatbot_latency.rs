//! Chatbot latency scenario — the paper's RQ2 analysis (§5.2.3):
//! batch-size vs throughput/latency trade-off under the two constraints
//! (RAM capacity, total-latency budget TTFT + TPOT·N).
//!
//! Sweeps batch size on each device for a q4_0 LLaMA-7B-class workload
//! and reports where throughput saturates (compute-bound knee) and which
//! configurations satisfy an interactive-chatbot latency budget.
//!
//!     cargo run --release --example chatbot_latency

use anyhow::Result;

use elib::device::{Accel, DeviceSpec, Workload};
use elib::metrics;
use elib::model::{scale, LlamaConfig};
use elib::quant::QuantType;
use elib::util::table::{f2, Table};

fn main() -> Result<()> {
    let cfg = LlamaConfig::llama_7b();
    let q = QuantType::Q4_0;
    let prompt = 64;
    let n_out = 100; // response length for the latency budget
    let budget_secs = 60.0;

    for device in DeviceSpec::paper_devices() {
        let mut t = Table::new(&[
            "batch", "agg tok/s", "per-seq tok/s", "TTFT (s)", "total lat (s)",
            "RAM need", "verdict",
        ])
        .left_cols(1)
        .title(&format!(
            "{}: batch sweep, q4_0 7B workload, GPU accel (budget {budget_secs}s for {n_out} tokens)",
            device.name
        ));
        let mut best_ok: Option<(usize, f64)> = None;
        let mut prev_agg = 0.0;
        let mut knee_reported = false;
        for batch in [1usize, 2, 4, 8, 16, 32, 64] {
            let w = Workload::decode(&cfg, q, batch, 256);
            let tpot = device.tpot(&w, Accel::Gpu, 4);
            let agg = batch as f64 / tpot;
            let per_seq = 1.0 / tpot;
            let ttft = device.ttft(&w, prompt, Accel::Gpu, 4);
            let total = metrics::total_latency(ttft, tpot, n_out);
            let need = scale::max_ram_bytes(&cfg, q, batch);
            let fits = device.fits_ram(need);
            let in_budget = total <= budget_secs;
            let verdict = match (fits, in_budget) {
                (false, _) => "RAM overflow (RQ2 c1)",
                (_, false) => "over budget (RQ2 c2)",
                _ => {
                    if best_ok.map_or(true, |(_, a)| agg > a) {
                        best_ok = Some((batch, agg));
                    }
                    "ok"
                }
            };
            // Compute-bound knee: aggregate throughput stops scaling.
            let knee = prev_agg > 0.0 && agg < prev_agg * 1.3 && !knee_reported;
            if knee {
                knee_reported = true;
            }
            prev_agg = agg;
            t.row(vec![
                format!("{batch}{}", if knee { " <- knee" } else { "" }),
                f2(agg),
                f2(per_seq),
                f2(ttft),
                f2(total),
                elib::util::table::human_bytes(need),
                verdict.into(),
            ]);
        }
        println!("{}", t.render());
        match best_ok {
            Some((b, a)) => println!(
                "  -> best feasible batch on {}: {b} ({a:.1} tok/s aggregate)\n",
                device.name
            ),
            None => println!("  -> no feasible batch on {} under this budget\n", device.name),
        }
    }
    println!("paper shape: batching multiplies aggregate throughput until the");
    println!("compute-bound knee, at the cost of per-request latency (§5.2.3).");
    Ok(())
}
