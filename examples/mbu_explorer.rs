//! MBU explorer — the paper's RQ1/RQ3 analyses (§5.2): what maximizes
//! Model Bandwidth Utilization, and where it becomes unpredictable.
//!
//! RQ1: sweeps the three levers the paper names — batch size, sequence
//! length, and KV-cache precision — and prints their MBU effect.
//! RQ3: shows the accelerator-precision unpredictability by comparing
//! simulated perplexity across devices' GPU paths.
//!
//!     make artifacts && cargo run --release --example mbu_explorer

use anyhow::Result;

use elib::device::{Accel, DeviceSpec, Workload};
use elib::metrics;
use elib::model::{scale, LlamaConfig};
use elib::quant::QuantType;
use elib::util::table::{f2, Table};

fn main() -> Result<()> {
    let cfg = LlamaConfig::llama_7b();
    let device = DeviceSpec::macbook();
    let accel = Accel::Gpu;

    // RQ1 lever 1: batch size.
    let mut t = Table::new(&["batch", "bytes/token", "TPOT (ms)", "MBU"])
        .left_cols(1)
        .title("RQ1a: batch size vs MBU (Macbook GPU, q4_0, ctx 256)");
    for batch in [1usize, 2, 4, 8, 16] {
        let w = Workload::decode(&cfg, QuantType::Q4_0, batch, 256);
        let tpot = device.tpot(&w, accel, 4);
        let mbu = metrics::mbu(w.param_bytes, w.kv_bytes, tpot, device.mem_bw);
        t.row(vec![
            batch.to_string(),
            elib::util::table::human_bytes(w.bytes_per_token),
            f2(tpot * 1e3),
            format!("{mbu:.3}"),
        ]);
    }
    println!("{}", t.render());

    // RQ1 lever 2: sequence (context) length.
    let mut t = Table::new(&["context", "kv bytes", "TPOT (ms)", "MBU"])
        .left_cols(1)
        .title("RQ1b: context length vs MBU (batch 4, q4_0)");
    for ctx in [64usize, 256, 512, 1024, 2048] {
        let w = Workload::decode(&cfg, QuantType::Q4_0, 4, ctx);
        let tpot = device.tpot(&w, accel, 4);
        let mbu = metrics::mbu(w.param_bytes, w.kv_bytes, tpot, device.mem_bw);
        t.row(vec![
            ctx.to_string(),
            elib::util::table::human_bytes(w.kv_bytes),
            f2(tpot * 1e3),
            format!("{mbu:.3}"),
        ]);
    }
    println!("{}", t.render());

    // RQ1 lever 3: KV-cache precision (f32 vs f16 vs q8-ish 1 byte).
    let mut t = Table::new(&["kv data byte", "kv bytes @2048", "note"])
        .left_cols(3)
        .title("RQ1c: KV-cache management — precision shrinks the cache (eq. 3)");
    for (db, note) in [(4u64, "f32"), (2, "f16 (llama.cpp default)"), (1, "q8 cache")] {
        let kv = scale::kv_cache_bytes(&cfg, 4, 2048, db);
        t.row(vec![
            db.to_string(),
            elib::util::table::human_bytes(kv),
            note.into(),
        ]);
    }
    println!("{}", t.render());

    // RQ3: unpredictability — the same model/format, wildly different
    // accuracy depending on the device's GPU stack.
    let mut t = Table::new(&["device", "framework", "ppl q4_0", "ppl q8_0", "verdict"])
        .left_cols(2)
        .title("RQ3: GPU-path accuracy unpredictability (base ppl 6.5)");
    for d in DeviceSpec::paper_devices() {
        let p4 = d.simulated_ppl(6.5, Accel::Gpu, QuantType::Q4_0);
        let p8 = d.simulated_ppl(6.5, Accel::Gpu, QuantType::Q8_0);
        let verdict = if p4 > 20.0 { "BROKEN (OpenCL pathology)" } else { "clean" };
        t.row(vec![
            d.name.into(),
            d.framework_gpu.into(),
            f2(p4),
            f2(p8),
            verdict.into(),
        ]);
    }
    println!("{}", t.render());
    println!("paper findings: MBU rises with batch until compute-bound; long contexts");
    println!("raise achieved bandwidth but steal it from weights; KV quantization frees");
    println!("bandwidth (RQ1). GPU accuracy is the unpredictable axis (RQ3).");
    Ok(())
}
