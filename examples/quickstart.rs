//! Quickstart — the end-to-end driver (DESIGN.md "End-to-end validation").
//!
//! Loads the *real, trained* tiny-LLaMA from `artifacts/` (built by
//! `make artifacts`), runs the automatic quantization flow, then for each
//! format: generates text with the native Model–Graph–Kernel engine,
//! evaluates held-out perplexity, and reports throughput / TPOT / MBU.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::path::Path;

use anyhow::Result;

use elib::coordinator::flow;
use elib::graph::{generate, Engine, Sampler};
use elib::kernel::BackendKind;
use elib::metrics;
use elib::model::{ByteTokenizer, ModelWeights};
use elib::quant::QuantType;
use elib::util::table::{f2, human_bytes, Table};

fn main() -> Result<()> {
    let artifacts = Path::new("artifacts");
    let original = artifacts.join("tiny_llama_f32.eguf");
    let (cfg, dense) = flow::load_original(&original)?;
    println!(
        "loaded trained tiny-llama: {} layers, d={}, vocab={} ({} params)",
        cfg.n_layers,
        cfg.d_model,
        cfg.vocab_size,
        cfg.n_params()
    );

    let eval = std::fs::read_to_string(artifacts.join("corpus_eval.txt"))?;
    let ppl_tokens: Vec<u32> = eval.bytes().take(512).map(|b| b as u32).collect();

    let tok = ByteTokenizer;
    let prompt = tok.encode("the inference engine ");
    const HOST_BW: f64 = 20e9; // assumed host DRAM peak for MBU accounting

    let mut table = Table::new(&[
        "quant", "model size", "tok/s", "TPOT (ms)", "MBU(host)", "ppl(held-out)",
    ])
    .left_cols(1)
    .title("quickstart: real generation + metrics per format (parallel backend, t4)");

    let mut sample = String::new();
    for q in [
        QuantType::F32,
        QuantType::Q8_0,
        QuantType::Q5_1,
        QuantType::Q5_0,
        QuantType::Q4_1,
        QuantType::Q4_0,
    ] {
        let mf = elib::model::testutil::build_model_file(&cfg, q, &dense);
        let weights = ModelWeights::load(&mf)?;
        let bytes_per_tok = weights.bytes_per_token();
        let total = weights.total_bytes();
        let mut engine = Engine::new(weights, BackendKind::Parallel(4));
        let stats = generate(&mut engine, &prompt, 48, &mut Sampler::Greedy)?;
        let (nll, n) = engine.sequence_nll(&ppl_tokens)?;
        let ppl = metrics::perplexity(nll, n);
        let mbu = metrics::mbu(bytes_per_tok, 0, stats.tpot_secs(), HOST_BW);
        table.row(vec![
            q.name().into(),
            human_bytes(total),
            f2(stats.decode_throughput()),
            f2(stats.tpot_secs() * 1e3),
            format!("{mbu:.3}"),
            format!("{ppl:.4}"),
        ]);
        if q == QuantType::Q4_0 {
            sample = tok.decode(&stats.tokens);
        }
    }
    println!("\n{}", table.render());
    println!("q4_0 greedy sample:\n  {}", sample.replace('\n', "\n  "));
    println!("\n(the model was trained for a few hundred steps on the synthetic corpus;");
    println!(" ppl ordering across formats is the real quantization effect — Fig 6's CPU rows)");
    Ok(())
}
