"""Deterministic synthetic corpus for training/evaluating the tiny model.

The paper evaluates perplexity on Wikitext-2 prompts; we have no external
data, so we generate a reproducible English-like corpus from a small
template grammar (DESIGN.md SS2 substitution). The generator is seeded and
pure-python so the corpus is bit-identical across runs and machines, and
the train/eval split is by document so held-out perplexity is meaningful.
"""

from __future__ import annotations

import random

SUBJECTS = [
    "the benchmark", "an edge device", "the inference engine", "a quantized model",
    "the memory bus", "the scheduler", "a mobile phone", "the laptop",
    "the accelerator", "a kernel", "the cache", "the compiler",
    "the battery", "a sensor", "the runtime", "the token stream",
]
VERBS = [
    "measures", "loads", "computes", "streams", "saturates", "evaluates",
    "quantizes", "decodes", "schedules", "profiles", "caches", "balances",
    "throttles", "predicts", "generates", "transfers",
]
OBJECTS = [
    "the weights", "a batch of requests", "the bandwidth", "every tensor",
    "the first token", "the attention scores", "a block of values",
    "the key value cache", "the output logits", "the power budget",
    "each layer", "the prompt", "the model file", "a memory page",
    "the thread pool", "the device memory",
]
ADVERBS = [
    "quickly", "slowly", "efficiently", "in parallel", "at the edge",
    "per token", "under load", "without stalling", "at peak bandwidth",
    "with low latency", "deterministically", "in four threads",
]
CONNECTIVES = ["meanwhile", "therefore", "in practice", "as a result",
               "by contrast", "at scale", "afterwards", "in theory"]


def _sentence(rng: random.Random) -> str:
    s = rng.choice(SUBJECTS)
    v = rng.choice(VERBS)
    o = rng.choice(OBJECTS)
    parts = [s, v, o]
    if rng.random() < 0.5:
        parts.append(rng.choice(ADVERBS))
    if rng.random() < 0.25:
        parts = [rng.choice(CONNECTIVES) + ","] + parts
    return " ".join(parts) + "."


def _document(rng: random.Random, n_sentences: int) -> str:
    return " ".join(_sentence(rng) for _ in range(n_sentences))


def generate(seed: int = 20250902, n_docs: int = 400, sentences_per_doc: int = 12) -> list[str]:
    """Generate the full corpus as a list of documents."""
    rng = random.Random(seed)
    return [_document(rng, sentences_per_doc) for _ in range(n_docs)]


def train_eval_split(docs: list[str], eval_fraction: float = 0.1) -> tuple[str, str]:
    """Split by document (every k-th doc held out), join with newlines."""
    k = max(2, int(round(1.0 / max(eval_fraction, 1e-6))))
    train = [d for i, d in enumerate(docs) if i % k != 0]
    evald = [d for i, d in enumerate(docs) if i % k == 0]
    return "\n".join(train) + "\n", "\n".join(evald) + "\n"


def tokens_from_text(text: str) -> list[int]:
    """Byte-level tokenization — must match rust's ByteTokenizer."""
    return list(text.encode("utf-8"))
