"""Layer-1 Pallas kernels (interpret=True) and pure-jnp oracles."""
