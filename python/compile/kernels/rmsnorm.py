"""Pallas RMSNorm kernel (single-block: the whole vector fits VMEM for
any realistic d_model; bandwidth-trivial next to the matmuls)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...]
    ss = jnp.mean(x * x)
    o_ref[...] = x * (1.0 / jnp.sqrt(ss + eps)) * w_ref[...]


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """x / rms(x) * weight, x: [d]."""
    (d,) = x.shape
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(x, weight)
