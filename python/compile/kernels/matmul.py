"""Pallas tiled mat-vec kernel — the decode hot-spot (one token's
projection through a weight matrix).

Hardware adaptation (DESIGN.md SSHardware-Adaptation): the paper's NEON /
OpenCL inner loops stream weight rows through registers; on TPU the same
insight is expressed as a BlockSpec that tiles the weight matrix HBM->VMEM
in row panels sized for VMEM, with the activation vector resident. The
MXU sees (tile_rows x cols) x (cols x 1) matmuls. interpret=True on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-panel height. 8 panels of the tiny model's largest matrix
# (352x128 f32) are ~45 KiB — far under VMEM; on a real TPU this would be
# raised to 128/256 (see DESIGN.md SSPerf L1 table).
DEFAULT_TILE_ROWS = 32


def _matvec_kernel(w_ref, x_ref, o_ref):
    # One grid step owns a (tile_rows, cols) weight panel in VMEM.
    o_ref[...] = w_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def matvec(w: jnp.ndarray, x: jnp.ndarray, tile_rows: int = DEFAULT_TILE_ROWS) -> jnp.ndarray:
    """out[r] = dot(w[r], x) with w: [rows, cols], x: [cols]."""
    rows, cols = w.shape
    assert rows % tile_rows == 0, f"rows {rows} % tile {tile_rows}"
    grid = (rows // tile_rows,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(w, x)


def vmem_bytes_estimate(rows: int, cols: int, tile_rows: int = DEFAULT_TILE_ROWS) -> int:
    """Analytic VMEM footprint of one grid step (perf-pass accounting):
    weight panel + x + output tile, f32."""
    return (tile_rows * cols + cols + tile_rows) * 4
