"""Pure-jnp oracles for every Pallas kernel — the correctness ground
truth pytest compares against (and the implementation the trainer uses,
since it must be differentiable and fast under jit)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matvec_ref(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """out[r] = dot(w[r, :], x). w: [rows, cols], x: [cols]."""
    return w @ x


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """x / rms(x) * weight over the last axis."""
    ss = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ss + eps)) * weight


def softmax_ref(x: jnp.ndarray) -> jnp.ndarray:
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def decode_attention_ref(
    q: jnp.ndarray,      # [n_heads, head_dim]
    k_cache: jnp.ndarray,  # [seq, n_heads, head_dim] (MHA: kv heads == heads)
    v_cache: jnp.ndarray,  # [seq, n_heads, head_dim]
    pos: jnp.ndarray,    # scalar int32: current position (cache holds 0..pos)
) -> jnp.ndarray:
    """Single-token decode attention with causal masking by `pos`.

    Returns [n_heads, head_dim].
    """
    seq, n_heads, head_dim = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    # scores[h, s] = q[h] . k_cache[s, h]
    scores = jnp.einsum("hd,shd->hs", q, k_cache) * scale
    mask = jnp.arange(seq)[None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    probs = softmax_ref(scores)
    return jnp.einsum("hs,shd->hd", probs, v_cache)


def rope_ref(x: jnp.ndarray, pos: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """LLaMA rotary embedding, matching the rust engine: for head vector
    x[..., d], rotate pairs (x[i], x[i+d/2]) by pos * theta^(-2i/d).

    x: [..., head_dim]; pos: scalar or [...] broadcastable position.
    """
    d = x.shape[-1]
    half = d // 2
    i = jnp.arange(half, dtype=jnp.float32)
    freq = theta ** (-2.0 * i / d)
    angle = jnp.asarray(pos, jnp.float32)[..., None] * freq  # [..., half]
    sin, cos = jnp.sin(angle), jnp.cos(angle)
    a, b = x[..., :half], x[..., half:]
    return jnp.concatenate([a * cos - b * sin, a * sin + b * cos], axis=-1)


# ---- q8_0 block quantization oracle (GGML layout, rust-compatible) ----

QK = 32
Q8_BLOCK_BYTES = 34  # 2-byte f16 scale + 32 int8 quants


def quantize_q8_0_ref(w: jnp.ndarray) -> jnp.ndarray:
    """Pack a [rows, cols] f32 matrix into GGML q8_0 row bytes
    [rows, cols/32*34] (uint8), bit-compatible with rust's
    quant::blocks::row_q8_0."""
    rows, cols = w.shape
    assert cols % QK == 0
    blocks = w.reshape(rows, cols // QK, QK)
    amax = jnp.max(jnp.abs(blocks), axis=-1)
    d = (amax / 127.0).astype(jnp.float16)  # RNE, same as rust round_f16
    inv = jnp.where(d == 0, 0.0, 1.0 / d.astype(jnp.float32))
    q = jnp.clip(jnp.round(blocks * inv[..., None]), -127, 127).astype(jnp.int8)
    d_bytes = jax.lax.bitcast_convert_type(d, jnp.uint8)  # [rows, nb, 2] LE
    q_bytes = jax.lax.bitcast_convert_type(q, jnp.uint8)  # [rows, nb, 32]
    return jnp.concatenate([d_bytes, q_bytes], axis=-1).reshape(rows, -1)


def dequantize_q8_0_ref(packed: jnp.ndarray, cols: int) -> jnp.ndarray:
    """Inverse of quantize_q8_0_ref (up to quantization error)."""
    rows = packed.shape[0]
    nb = cols // QK
    blocks = packed.reshape(rows, nb, Q8_BLOCK_BYTES)
    d = jax.lax.bitcast_convert_type(blocks[..., :2], jnp.float16)
    d = d.reshape(rows, nb).astype(jnp.float32)
    q = jax.lax.bitcast_convert_type(blocks[..., 2:], jnp.int8).reshape(rows, nb, QK)
    return (q.astype(jnp.float32) * d[..., None]).reshape(rows, cols)


def q8_matvec_ref(packed: jnp.ndarray, x: jnp.ndarray, cols: int) -> jnp.ndarray:
    """Dequantize-then-matvec oracle for the q8_0 dequant-matmul kernel."""
    return dequantize_q8_0_ref(packed, cols) @ x
