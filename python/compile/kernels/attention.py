"""Pallas single-token decode-attention kernel.

One grid step per head: the head's query, the head's full K/V cache
columns and the causal mask live in VMEM; scores, a numerically-stable
softmax and the value mix happen without returning to HBM — the
flash-style single-row variant of the paper's OpenCL threadgroup
attention (DESIGN.md SSHardware-Adaptation). interpret=True on CPU.

MHA only (n_kv_heads == n_heads), which the tiny evaluation model
satisfies; the jnp oracle `ref.decode_attention_ref` covers GQA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref):
    # Shapes per grid step (head h): q [1, d], k/v [S, 1, d], mask [S].
    q = q_ref[...]           # [1, d]
    k = k_ref[...][:, 0, :]  # [S, d]
    v = v_ref[...][:, 0, :]  # [S, d]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    scores = (k @ q[0]) * scale          # [S]
    scores = jnp.where(mask_ref[...], scores, -1e30)
    m = jnp.max(scores)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e)
    o_ref[...] = (probs @ v)[None, :]    # [1, d]


@jax.jit
def decode_attention(
    q: jnp.ndarray,        # [n_heads, head_dim]
    k_cache: jnp.ndarray,  # [seq, n_heads, head_dim]
    v_cache: jnp.ndarray,  # [seq, n_heads, head_dim]
    pos: jnp.ndarray,      # scalar int32
) -> jnp.ndarray:
    seq, n_heads, head_dim = k_cache.shape
    mask = jnp.arange(seq) <= pos
    return pl.pallas_call(
        _decode_attn_kernel,
        grid=(n_heads,),
        in_specs=[
            pl.BlockSpec((1, head_dim), lambda h: (h, 0)),
            pl.BlockSpec((seq, 1, head_dim), lambda h: (0, h, 0)),
            pl.BlockSpec((seq, 1, head_dim), lambda h: (0, h, 0)),
            pl.BlockSpec((seq,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((1, head_dim), lambda h: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((n_heads, head_dim), jnp.float32),
        interpret=True,
    )(q, k_cache, v_cache, mask)


def vmem_bytes_estimate(seq: int, head_dim: int) -> int:
    """Per-head VMEM: K tile + V tile + q + mask + scores, f32."""
    return (2 * seq * head_dim + 2 * head_dim + 2 * seq) * 4
