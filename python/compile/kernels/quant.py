"""Pallas q8_0 dequantize-matvec kernel — the paper's core mechanism
(stream few bytes, dequantize near compute) expressed for TPU.

Weight rows arrive as GGML q8_0 packed bytes (2-byte f16 scale + 32 int8
per 32-weight block, the exact layout rust's quant::blocks::row_q8_0
writes into EGUF files). The BlockSpec moves the *packed* row panel
HBM->VMEM — 8.5 bits/weight of traffic instead of 32 — and dequantization
happens in VMEM right before the MXU-shaped matvec, mirroring how
llama.cpp dequantizes into NEON registers after the DRAM fetch.
interpret=True on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QK = 32
BLOCK_BYTES = 34


def _unpack_q8_0(panel: jnp.ndarray, cols: int) -> jnp.ndarray:
    """panel: [tile_rows, cols//QK * 34] uint8 -> [tile_rows, cols] f32."""
    tile_rows = panel.shape[0]
    nb = cols // QK
    blocks = panel.reshape(tile_rows, nb, BLOCK_BYTES)
    d = jax.lax.bitcast_convert_type(blocks[..., :2], jnp.float16)
    d = d.reshape(tile_rows, nb).astype(jnp.float32)
    q = jax.lax.bitcast_convert_type(blocks[..., 2:], jnp.int8)
    q = q.reshape(tile_rows, nb, QK).astype(jnp.float32)
    return (q * d[..., None]).reshape(tile_rows, cols)


def _q8_matvec_kernel(w_ref, x_ref, o_ref, *, cols: int):
    w = _unpack_q8_0(w_ref[...], cols)  # dequant in VMEM, post-transfer
    o_ref[...] = w @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("cols", "tile_rows"))
def q8_matvec(
    packed: jnp.ndarray,  # [rows, cols//32*34] uint8 (GGML q8_0 rows)
    x: jnp.ndarray,       # [cols] f32
    cols: int,
    tile_rows: int = 32,
) -> jnp.ndarray:
    rows, row_bytes = packed.shape
    assert row_bytes == cols // QK * BLOCK_BYTES, (row_bytes, cols)
    assert rows % tile_rows == 0, f"rows {rows} % tile {tile_rows}"
    return pl.pallas_call(
        functools.partial(_q8_matvec_kernel, cols=cols),
        grid=(rows // tile_rows,),
        in_specs=[
            pl.BlockSpec((tile_rows, row_bytes), lambda i: (i, 0)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(packed, x)


def hbm_bytes_per_call(rows: int, cols: int) -> int:
    """Packed traffic: the kernel's whole point — 34 bytes per 32 weights
    instead of 128."""
    return rows * (cols // QK) * BLOCK_BYTES + cols * 4 + rows * 4
