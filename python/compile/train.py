"""Trainer for the tiny evaluation model (L2 fwd/bwd).

A few hundred AdamW steps on the synthetic corpus — enough to pull
held-out perplexity far below the 256-way uniform baseline so the
quantization formats produce *real*, ordered accuracy deltas in the
Fig-6 reproduction. Deterministic given the seed.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod
from . import model as model_mod


def make_batches(tokens: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    """Random contiguous windows, deterministic."""
    rng = np.random.default_rng(seed)
    starts_max = len(tokens) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, starts_max, size=batch)
        yield np.stack([tokens[s : s + seq + 1] for s in starts])


def adamw_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


@functools.partial(jax.jit, static_argnames=("lr", "wd"))
def adamw_step(params, opt, grads, lr=3e-3, wd=0.01, b1=0.9, b2=0.98, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads)
    tf = t.astype(jnp.float32)
    bc1 = 1 - b1 ** tf
    bc2 = 1 - b2 ** tf
    def upd(p, m_, v_):
        return p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) - lr * wd * p
    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def train(
    cfg: dict = model_mod.TINY_CONFIG,
    steps: int = 400,
    batch: int = 16,
    seq: int = 128,
    seed: int = 0,
    log_every: int = 50,
    log=print,
):
    """Train and return (params, loss_history)."""
    docs = corpus_mod.generate()
    train_text, _ = corpus_mod.train_eval_split(docs)
    tokens = np.asarray(corpus_mod.tokens_from_text(train_text), dtype=np.int32)

    params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)

    loss_grad = jax.jit(
        jax.value_and_grad(lambda p, b: model_mod.loss_fn(p, cfg, b))
    )
    history = []
    t0 = time.time()
    for step, batch_np in enumerate(
        make_batches(tokens, batch, seq, steps, seed=seed + 1)
    ):
        loss, grads = loss_grad(params, jnp.asarray(batch_np))
        params, opt = adamw_step(params, opt, grads)
        history.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            log(f"step {step:4d}  loss {float(loss):.4f}  ({time.time()-t0:.0f}s)")
    return params, history


def eval_ppl(params, cfg: dict, max_tokens: int = 4096) -> float:
    """Held-out byte perplexity via the batched forward."""
    docs = corpus_mod.generate()
    _, eval_text = corpus_mod.train_eval_split(docs)
    toks = np.asarray(corpus_mod.tokens_from_text(eval_text)[:max_tokens], np.int32)
    seq = 128
    n_chunks = (len(toks) - 1) // seq
    nll_sum, count = 0.0, 0
    fwd = jax.jit(lambda p, t: model_mod.forward_ref(p, cfg, t))
    for c in range(n_chunks):
        chunk = toks[c * seq : (c + 1) * seq + 1]
        logits = fwd(params, jnp.asarray(chunk[None, :-1]))
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = chunk[1:]
        nll = -np.take_along_axis(np.asarray(logp[0]), tgt[:, None], axis=-1)
        nll_sum += float(nll.sum())
        count += len(tgt)
    return float(np.exp(nll_sum / count))
