"""L2: the tiny-LLaMA evaluation model in JAX.

Two forward implementations over the same parameters:

* ``forward_ref``   — batched, differentiable, pure-jnp (training path);
* ``decode_step``   — single-token decode with an explicit KV cache,
  built on the L1 Pallas kernels (AOT/benchmark path). A ``use_pallas``
  switch selects the jnp oracles instead, which the tests use to prove
  kernel/oracle equivalence at model level.
* ``decode_step_q8``— same decode but projection weights arrive as GGML
  q8_0 packed bytes and go through the Pallas dequant-matvec kernel.

Parameter names and matrix orientation ([out_features, in_features],
``x @ W.T``) match the rust engine's EGUF expectations exactly.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import matmul as matmul_k
from .kernels import quant as quant_k
from .kernels import ref
from .kernels import rmsnorm as rmsnorm_k

# Must mirror rust `LlamaConfig::tiny()` (cross-checked in integration
# tests via the EGUF metadata round-trip).
TINY_CONFIG = dict(
    vocab_size=256,
    d_model=128,
    n_layers=4,
    n_heads=4,
    n_kv_heads=4,
    d_ff=352,
    max_seq_len=256,
    rope_theta=10000.0,
    norm_eps=1e-5,
)

Params = Dict[str, jnp.ndarray]


def param_order(cfg: dict) -> list[str]:
    """Canonical tensor order — the EGUF export order and the order the
    rust runtime feeds PJRT parameters in."""
    names = ["tok_emb", "out_norm", "lm_head"]
    for l in range(cfg["n_layers"]):
        for t in ["wq", "wk", "wv", "wo", "w1", "w2", "w3", "attn_norm", "ffn_norm"]:
            names.append(f"layers.{l}.{t}")
    return names


def init_params(cfg: dict, key: jax.Array) -> Params:
    d, v, ff = cfg["d_model"], cfg["vocab_size"], cfg["d_ff"]
    kv = cfg["n_kv_heads"] * d // cfg["n_heads"]
    shapes = {
        "tok_emb": (v, d),
        "out_norm": (d,),
        "lm_head": (v, d),
    }
    for l in range(cfg["n_layers"]):
        shapes[f"layers.{l}.wq"] = (d, d)
        shapes[f"layers.{l}.wk"] = (kv, d)
        shapes[f"layers.{l}.wv"] = (kv, d)
        shapes[f"layers.{l}.wo"] = (d, d)
        shapes[f"layers.{l}.w1"] = (ff, d)
        shapes[f"layers.{l}.w2"] = (d, ff)
        shapes[f"layers.{l}.w3"] = (ff, d)
        shapes[f"layers.{l}.attn_norm"] = (d,)
        shapes[f"layers.{l}.ffn_norm"] = (d,)
    params: Params = {}
    for name, shape in shapes.items():
        if "norm" in name:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            key, sub = jax.random.split(key)
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(d)
            )
    return params


# ----------------------------------------------------------- training path

def forward_ref(params: Params, cfg: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    """Batched causal forward: tokens [B, T] -> logits [B, T, V]."""
    B, T = tokens.shape
    H, hd = cfg["n_heads"], cfg["d_model"] // cfg["n_heads"]
    KVH = cfg["n_kv_heads"]
    x = params["tok_emb"][tokens]  # [B, T, d]
    pos = jnp.arange(T)
    causal = pos[None, :] <= pos[:, None]  # [T, T] query x key
    for l in range(cfg["n_layers"]):
        p = lambda s: params[f"layers.{l}.{s}"]
        xn = ref.rmsnorm_ref(x, p("attn_norm"), cfg["norm_eps"])
        q = (xn @ p("wq").T).reshape(B, T, H, hd)
        k = (xn @ p("wk").T).reshape(B, T, KVH, hd)
        v = (xn @ p("wv").T).reshape(B, T, KVH, hd)
        q = ref.rope_ref(q.swapaxes(1, 2), pos, cfg["rope_theta"]).swapaxes(1, 2)
        k = ref.rope_ref(k.swapaxes(1, 2), pos, cfg["rope_theta"]).swapaxes(1, 2)
        if KVH != H:
            rep = H // KVH
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        scores = jnp.where(causal[None, None], scores, -1e30)
        probs = ref.softmax_ref(scores)
        att = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(B, T, -1)
        x = x + att @ p("wo").T
        xn = ref.rmsnorm_ref(x, p("ffn_norm"), cfg["norm_eps"])
        gate = xn @ p("w1").T
        up = xn @ p("w3").T
        x = x + (jax.nn.silu(gate) * up) @ p("w2").T
    xn = ref.rmsnorm_ref(x, params["out_norm"], cfg["norm_eps"])
    return xn @ params["lm_head"].T


def loss_fn(params: Params, cfg: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy over [B, T] byte tokens."""
    logits = forward_ref(params, cfg, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ------------------------------------------------------------- decode path

def _matvec(w, x, use_pallas: bool):
    return matmul_k.matvec(w, x) if use_pallas else ref.matvec_ref(w, x)


def _rmsnorm(x, g, eps, use_pallas: bool):
    return rmsnorm_k.rmsnorm(x, g, eps) if use_pallas else ref.rmsnorm_ref(x, g, eps)


def decode_step(
    params: Params,
    cfg: dict,
    token: jnp.ndarray,    # scalar int32
    pos: jnp.ndarray,      # scalar int32
    k_cache: jnp.ndarray,  # [L, S, H, hd]
    v_cache: jnp.ndarray,  # [L, S, H, hd]
    use_pallas: bool = True,
):
    """One decode step; returns (logits [V], k_cache', v_cache').

    Requires MHA (n_kv_heads == n_heads) on the pallas path.
    """
    H, hd = cfg["n_heads"], cfg["d_model"] // cfg["n_heads"]
    eps = cfg["norm_eps"]
    x = params["tok_emb"][token]
    for l in range(cfg["n_layers"]):
        p = lambda s: params[f"layers.{l}.{s}"]
        xn = _rmsnorm(x, p("attn_norm"), eps, use_pallas)
        q = _matvec(p("wq"), xn, use_pallas).reshape(H, hd)
        k = _matvec(p("wk"), xn, use_pallas).reshape(H, hd)
        v = _matvec(p("wv"), xn, use_pallas).reshape(H, hd)
        q = ref.rope_ref(q, pos, cfg["rope_theta"])
        k = ref.rope_ref(k, pos, cfg["rope_theta"])
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None, None], (l, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None, None], (l, pos, 0, 0))
        if use_pallas:
            att = attn_k.decode_attention(q, k_cache[l], v_cache[l], pos)
        else:
            att = ref.decode_attention_ref(q, k_cache[l], v_cache[l], pos)
        x = x + _matvec(p("wo"), att.reshape(-1), use_pallas)
        xn = _rmsnorm(x, p("ffn_norm"), eps, use_pallas)
        gate = _matvec(p("w1"), xn, use_pallas)
        up = _matvec(p("w3"), xn, use_pallas)
        x = x + _matvec(p("w2"), jax.nn.silu(gate) * up, use_pallas)
    xn = _rmsnorm(x, params["out_norm"], eps, use_pallas)
    logits = _matvec(params["lm_head"], xn, use_pallas)
    return logits, k_cache, v_cache


def pack_params_q8(params: Params, cfg: dict) -> Params:
    """Pack every projection matrix as GGML q8_0 bytes; norms stay f32."""
    out: Params = {}
    for name, w in params.items():
        if "norm" in name:
            out[name] = w
        else:
            out[name] = ref.quantize_q8_0_ref(w)
    return out


def decode_step_q8(
    packed: Params,
    cfg: dict,
    token: jnp.ndarray,
    pos: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
):
    """Decode step with q8_0-packed weights through the Pallas
    dequant-matvec kernel (embedding/table lookups dequantize in-graph)."""
    d, ff = cfg["d_model"], cfg["d_ff"]
    H, hd = cfg["n_heads"], d // cfg["n_heads"]
    eps = cfg["norm_eps"]
    kv = cfg["n_kv_heads"] * hd

    emb = ref.dequantize_q8_0_ref(packed["tok_emb"], d)
    x = emb[token]
    for l in range(cfg["n_layers"]):
        p = lambda s: packed[f"layers.{l}.{s}"]
        xn = rmsnorm_k.rmsnorm(x, p("attn_norm"), eps)
        q = quant_k.q8_matvec(p("wq"), xn, d).reshape(H, hd)
        k = quant_k.q8_matvec(p("wk"), xn, d).reshape(H, hd)
        v = quant_k.q8_matvec(p("wv"), xn, d).reshape(H, hd)
        q = ref.rope_ref(q, pos, cfg["rope_theta"])
        k = ref.rope_ref(k, pos, cfg["rope_theta"])
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None, None], (l, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None, None], (l, pos, 0, 0))
        att = attn_k.decode_attention(q, k_cache[l], v_cache[l], pos)
        x = x + quant_k.q8_matvec(p("wo"), att.reshape(-1), d)
        xn = rmsnorm_k.rmsnorm(x, p("ffn_norm"), eps)
        gate = quant_k.q8_matvec(p("w1"), xn, d)
        up = quant_k.q8_matvec(p("w3"), xn, d)
        x = x + quant_k.q8_matvec(p("w2"), jax.nn.silu(gate) * up, ff)
    xn = rmsnorm_k.rmsnorm(x, packed["out_norm"], eps)
    logits = quant_k.q8_matvec(packed["lm_head"], xn, d)
    return logits, k_cache, v_cache
    _ = kv


def empty_cache(cfg: dict):
    L, S = cfg["n_layers"], cfg["max_seq_len"]
    H, hd = cfg["n_heads"], cfg["d_model"] // cfg["n_heads"]
    z = jnp.zeros((L, S, H, hd), jnp.float32)
    return z, z


def decode_sequence(params: Params, cfg: dict, tokens, use_pallas=False):
    """Feed tokens sequentially through decode_step; returns final logits.
    Test helper proving decode == batched forward_ref."""
    k_cache, v_cache = empty_cache(cfg)
    logits = None
    for i, t in enumerate(tokens):
        logits, k_cache, v_cache = decode_step(
            params, cfg,
            jnp.asarray(t, jnp.int32), jnp.asarray(i, jnp.int32),
            k_cache, v_cache, use_pallas=use_pallas,
        )
    return logits
