"""EGUF writer (python side): exports the trained f32 weights in the
exact container format rust's gguf::ModelFile reads (see
rust/src/gguf/mod.rs for the layout). The rust quantization flow then
produces the five quantized variants from this one file."""

from __future__ import annotations

import json
import struct
from typing import Dict

import numpy as np

MAGIC = b"EGUF"
VERSION = 1
QTYPE_F32 = 0


def config_meta(cfg: dict, qtype: str = "f32") -> dict:
    return {
        "arch": "tiny-llama",
        "qtype": qtype,
        "config": {
            "vocab_size": cfg["vocab_size"],
            "d_model": cfg["d_model"],
            "n_layers": cfg["n_layers"],
            "n_heads": cfg["n_heads"],
            "n_kv_heads": cfg["n_kv_heads"],
            "d_ff": cfg["d_ff"],
            "max_seq_len": cfg["max_seq_len"],
            "rope_theta": cfg["rope_theta"],
            "norm_eps": cfg["norm_eps"],
        },
    }


def write_eguf(path: str, meta: dict, tensors: Dict[str, np.ndarray]) -> None:
    """tensors: name -> f32 array of shape [rows, cols] or [cols]
    (1-D arrays are stored as a single row, matching rust norm vectors)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", VERSION))
        blob = json.dumps(meta).encode("utf-8")
        f.write(struct.pack("<Q", len(blob)))
        f.write(blob)
        f.write(struct.pack("<Q", len(tensors)))
        for name, arr in tensors.items():
            a = np.asarray(arr, dtype=np.float32)
            if a.ndim == 1:
                a = a[None, :]
            assert a.ndim == 2, f"{name}: rank {a.ndim}"
            rows, cols = a.shape
            data = a.astype("<f4").tobytes()
            nb = name.encode("utf-8")
            f.write(struct.pack("<Q", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", QTYPE_F32))
            f.write(struct.pack("<Q", rows))
            f.write(struct.pack("<Q", cols))
            f.write(struct.pack("<Q", len(data)))
            f.write(data)


def read_eguf_f32(path: str):
    """Minimal reader (tests): returns (meta, {name: np.ndarray})."""
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC
        (version,) = struct.unpack("<I", f.read(4))
        assert version == VERSION
        (mlen,) = struct.unpack("<Q", f.read(8))
        meta = json.loads(f.read(mlen).decode("utf-8"))
        (n,) = struct.unpack("<Q", f.read(8))
        tensors = {}
        for _ in range(n):
            (nlen,) = struct.unpack("<Q", f.read(8))
            name = f.read(nlen).decode("utf-8")
            (qt,) = struct.unpack("<I", f.read(4))
            assert qt == QTYPE_F32
            rows, cols, dlen = struct.unpack("<QQQ", f.read(24))
            data = np.frombuffer(f.read(dlen), dtype="<f4").reshape(rows, cols)
            tensors[name] = data
        return meta, tensors
