"""ELIB compile path: L2 jax model + L1 pallas kernels, AOT-lowered to HLO text."""
