"""AOT driver: corpus -> train -> export EGUF -> lower HLO text.

Run once by `make artifacts`; python never appears on the benchmark path.

Outputs (in --out-dir, default ../artifacts):
  corpus_train.txt / corpus_eval.txt   the synthetic corpus split
  weights.npz                          trained f32 params (train cache)
  tiny_llama_f32.eguf                  weights in the rust container format
  decode_f32.hlo.txt                   Pallas decode step, f32 weight params
  decode_q8_0.hlo.txt                  Pallas dequant-matvec decode, packed
                                       q8_0 u8 weight params
  model_meta.json                      config + parameter feed order + stats

HLO *text* (not serialized proto) is the interchange format: jax >= 0.5
emits 64-bit instruction ids that the image's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus as corpus_mod
from . import export as export_mod
from . import model as model_mod
from . import train as train_mod


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def load_or_train(out_dir: str, steps: int, retrain: bool):
    cache = os.path.join(out_dir, "weights.npz")
    if os.path.exists(cache) and not retrain:
        data = np.load(cache)
        params = {k: jnp.asarray(data[k]) for k in data.files if k != "__loss__"}
        history = list(data["__loss__"]) if "__loss__" in data.files else []
        print(f"[aot] loaded cached weights from {cache}")
        return params, history
    print(f"[aot] training tiny-llama for {steps} steps …")
    params, history = train_mod.train(steps=steps)
    np.savez(
        cache,
        __loss__=np.asarray(history, np.float32),
        **{k: np.asarray(v) for k, v in params.items()},
    )
    return params, history


def lower_decode_f32(params, cfg) -> str:
    order = model_mod.param_order(cfg)

    def fn(token, pos, k_cache, v_cache, *weights):
        p = dict(zip(order, weights))
        return model_mod.decode_step(p, cfg, token, pos, k_cache, v_cache,
                                     use_pallas=True)

    spec = lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
    kc, vc = model_mod.empty_cache(cfg)
    args = [
        jax.ShapeDtypeStruct((), np.int32),
        jax.ShapeDtypeStruct((), np.int32),
        spec(kc),
        spec(vc),
    ] + [spec(params[n]) for n in order]
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_decode_q8(packed, cfg) -> str:
    order = model_mod.param_order(cfg)

    def fn(token, pos, k_cache, v_cache, *weights):
        p = dict(zip(order, weights))
        return model_mod.decode_step_q8(p, cfg, token, pos, k_cache, v_cache)

    spec = lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
    kc, vc = model_mod.empty_cache(cfg)
    args = [
        jax.ShapeDtypeStruct((), np.int32),
        jax.ShapeDtypeStruct((), np.int32),
        spec(kc),
        spec(vc),
    ] + [spec(packed[n]) for n in order]
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    cfg = model_mod.TINY_CONFIG
    t0 = time.time()

    # 1. Corpus.
    docs = corpus_mod.generate()
    train_text, eval_text = corpus_mod.train_eval_split(docs)
    with open(os.path.join(out, "corpus_train.txt"), "w") as f:
        f.write(train_text)
    with open(os.path.join(out, "corpus_eval.txt"), "w") as f:
        f.write(eval_text)
    print(f"[aot] corpus: {len(train_text)} train / {len(eval_text)} eval bytes")

    # 2. Train (or reuse cache).
    params, history = load_or_train(out, args.steps, args.retrain)
    ppl = train_mod.eval_ppl(params, cfg)
    print(f"[aot] held-out byte perplexity: {ppl:.3f} (uniform would be 256)")

    # 3. EGUF export (rust quantization flow input).
    tensors = {n: np.asarray(params[n]) for n in model_mod.param_order(cfg)}
    eguf_path = os.path.join(out, "tiny_llama_f32.eguf")
    export_mod.write_eguf(eguf_path, export_mod.config_meta(cfg), tensors)
    print(f"[aot] wrote {eguf_path} ({os.path.getsize(eguf_path)} bytes)")

    # 4. AOT-lower the decode steps to HLO text.
    hlo_f32 = lower_decode_f32(params, cfg)
    with open(os.path.join(out, "decode_f32.hlo.txt"), "w") as f:
        f.write(hlo_f32)
    print(f"[aot] decode_f32.hlo.txt: {len(hlo_f32)} chars")

    packed = model_mod.pack_params_q8(params, cfg)
    hlo_q8 = lower_decode_q8(packed, cfg)
    with open(os.path.join(out, "decode_q8_0.hlo.txt"), "w") as f:
        f.write(hlo_q8)
    print(f"[aot] decode_q8_0.hlo.txt: {len(hlo_q8)} chars")

    # 5. Metadata for the rust runtime.
    meta = {
        "config": export_mod.config_meta(cfg)["config"],
        "param_order": model_mod.param_order(cfg),
        "artifacts": {
            "decode_f32": "decode_f32.hlo.txt",
            "decode_q8_0": "decode_q8_0.hlo.txt",
            "weights_f32": "tiny_llama_f32.eguf",
        },
        "train": {
            "steps": len(history),
            "final_loss": history[-1] if history else None,
            "eval_ppl": ppl,
        },
        "cache_shape": list(np.shape(model_mod.empty_cache(cfg)[0])),
    }
    with open(os.path.join(out, "model_meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[aot] done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
