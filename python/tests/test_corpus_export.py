"""Corpus determinism + EGUF export round-trip."""

import os
import tempfile

import numpy as np

from compile import corpus as corpus_mod
from compile import export as export_mod
from compile import model as model_mod


def test_corpus_is_deterministic():
    a = corpus_mod.generate(seed=1, n_docs=5)
    b = corpus_mod.generate(seed=1, n_docs=5)
    assert a == b
    c = corpus_mod.generate(seed=2, n_docs=5)
    assert a != c


def test_split_is_disjoint_and_covers():
    docs = corpus_mod.generate(n_docs=30)
    train, evald = corpus_mod.train_eval_split(docs, eval_fraction=0.1)
    tset = set(train.split("\n")) - {""}
    eset = set(evald.split("\n")) - {""}
    assert tset.isdisjoint(eset)
    assert len(tset) + len(eset) == 30


def test_tokens_are_bytes():
    toks = corpus_mod.tokens_from_text("abc\n")
    assert toks == [97, 98, 99, 10]
    assert all(0 <= t < 256 for t in corpus_mod.tokens_from_text("é世"))


def test_eguf_roundtrip():
    cfg = model_mod.TINY_CONFIG
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.normal(size=(4, 32)).astype(np.float32),
        "norm": rng.normal(size=(32,)).astype(np.float32),
    }
    meta = export_mod.config_meta(cfg)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.eguf")
        export_mod.write_eguf(path, meta, tensors)
        meta2, back = export_mod.read_eguf_f32(path)
        assert meta2["config"]["d_model"] == cfg["d_model"]
        np.testing.assert_array_equal(back["a"], tensors["a"])
        # 1-D tensors become single rows.
        assert back["norm"].shape == (1, 32)
        np.testing.assert_array_equal(back["norm"][0], tensors["norm"])


def test_eguf_header_bytes():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.eguf")
        export_mod.write_eguf(path, {"x": 1}, {})
        raw = open(path, "rb").read()
        assert raw[:4] == b"EGUF"
        assert raw[4:8] == (1).to_bytes(4, "little")
