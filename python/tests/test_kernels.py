"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes and value distributions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import matmul as matmul_k
from compile.kernels import quant as quant_k
from compile.kernels import ref
from compile.kernels import rmsnorm as rmsnorm_k

SETTINGS = dict(max_examples=20, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# --------------------------------------------------------------- matvec

@settings(**SETTINGS)
@given(
    rows_tiles=st.integers(1, 6),
    cols=st.sampled_from([32, 64, 128, 352]),
    seed=st.integers(0, 2**31),
)
def test_matvec_matches_ref(rows_tiles, cols, seed):
    rng = np.random.default_rng(seed)
    rows = rows_tiles * 32
    w, x = rand(rng, rows, cols), rand(rng, cols)
    got = matmul_k.matvec(w, x)
    want = ref.matvec_ref(w, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_matvec_rejects_unaligned_rows():
    rng = np.random.default_rng(0)
    with pytest.raises(AssertionError):
        matmul_k.matvec(rand(rng, 33, 32), rand(rng, 32))


def test_matvec_vmem_estimate_positive():
    assert matmul_k.vmem_bytes_estimate(352, 128) > 0


# -------------------------------------------------------------- rmsnorm

@settings(**SETTINGS)
@given(
    d=st.sampled_from([16, 128, 352]),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31),
)
def test_rmsnorm_matches_ref(d, scale, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, d) * scale
    g = rand(rng, d)
    got = rmsnorm_k.rmsnorm(x, g)
    want = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-3)


def test_rmsnorm_unit_vector():
    x = jnp.full((8,), 3.0)
    out = rmsnorm_k.rmsnorm(x, jnp.ones((8,)))
    np.testing.assert_allclose(np.asarray(out), np.ones(8), atol=1e-3)


# ------------------------------------------------------------ attention

@settings(**SETTINGS)
@given(
    heads=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([8, 64, 256]),
    hd=st.sampled_from([16, 32]),
    pos_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31),
)
def test_decode_attention_matches_ref(heads, seq, hd, pos_frac, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, heads, hd)
    k = rand(rng, seq, heads, hd)
    v = rand(rng, seq, heads, hd)
    pos = jnp.asarray(int(pos_frac * (seq - 1)), jnp.int32)
    got = attn_k.decode_attention(q, k, v, pos)
    want = ref.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_attention_respects_causal_mask():
    # With pos=0 the output must equal v[0] exactly (softmax over 1 entry).
    rng = np.random.default_rng(1)
    q, k, v = rand(rng, 2, 16), rand(rng, 32, 2, 16), rand(rng, 32, 2, 16)
    out = attn_k.decode_attention(q, k, v, jnp.asarray(0, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[0]), atol=1e-5)


def test_rope_matches_rust_convention():
    # rust kernel::rope_reference: pairs (i, i+half), freq theta^(-2i/d).
    x = jnp.asarray(np.arange(8, dtype=np.float32))
    out = np.asarray(ref.rope_ref(x, jnp.asarray(3), 10000.0))
    d, half, theta, pos = 8, 4, 10000.0, 3.0
    exp = np.zeros(8, np.float32)
    for i in range(half):
        f = theta ** (-2.0 * i / d)
        a, b = float(x[i]), float(x[i + half])
        s, c = np.sin(pos * f), np.cos(pos * f)
        exp[i] = a * c - b * s
        exp[i + half] = a * s + b * c
    np.testing.assert_allclose(out, exp, atol=1e-5)


# ----------------------------------------------------------------- q8_0

@settings(**SETTINGS)
@given(
    rows_tiles=st.integers(1, 4),
    cols_blocks=st.integers(1, 8),
    scale=st.floats(1e-3, 1e2),
    seed=st.integers(0, 2**31),
)
def test_q8_matvec_matches_ref(rows_tiles, cols_blocks, scale, seed):
    rng = np.random.default_rng(seed)
    rows, cols = rows_tiles * 32, cols_blocks * 32
    w = rand(rng, rows, cols) * scale
    x = rand(rng, cols)
    packed = ref.quantize_q8_0_ref(w)
    got = quant_k.q8_matvec(packed, x, cols)
    want = ref.q8_matvec_ref(packed, x, cols)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-3)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31))
def test_q8_roundtrip_error_bounded(seed):
    rng = np.random.default_rng(seed)
    w = rand(rng, 8, 64)
    back = ref.dequantize_q8_0_ref(ref.quantize_q8_0_ref(w), 64)
    amax = float(jnp.max(jnp.abs(w)))
    assert float(jnp.max(jnp.abs(back - w))) <= amax / 127.0 * 0.51 + amax / 1024.0


def test_q8_packed_layout_is_ggml():
    # Block = [d_lo, d_hi, q0..q31]; an all-127 block must store d=1.0
    # (f16 0x3c00) and quants 127.
    w = jnp.full((1, 32), 127.0, jnp.float32)
    packed = np.asarray(ref.quantize_q8_0_ref(w))
    assert packed.shape == (1, 34)
    assert packed[0, 0] == 0x00 and packed[0, 1] == 0x3C  # f16(1.0) LE
    assert (packed[0, 2:] == 127).all()


def test_q8_hbm_accounting():
    # 34 bytes per 32 weights.
    assert quant_k.hbm_bytes_per_call(32, 64) == 32 * 2 * 34 + 64 * 4 + 32 * 4
