"""L2 correctness: decode-with-cache == batched forward, pallas decode ==
jnp decode, q8 decode within quantization tolerance, loss/grads finite."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod

CFG = dict(model_mod.TINY_CONFIG)
# Small test config for speed (same structure).
CFG.update(n_layers=2, max_seq_len=32)


@pytest.fixture(scope="module")
def params():
    return model_mod.init_params(CFG, jax.random.PRNGKey(7))


def test_param_order_covers_all(params):
    assert set(model_mod.param_order(CFG)) == set(params.keys())


def test_forward_shapes(params):
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 256, (2, 16)))
    logits = model_mod.forward_ref(params, CFG, toks)
    assert logits.shape == (2, 16, CFG["vocab_size"])
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_decode_matches_batched_forward(params):
    """Token-at-a-time decode with the KV cache must reproduce the
    batched causal forward exactly (same math, different dataflow)."""
    toks = [5, 200, 13, 77, 42]
    batched = model_mod.forward_ref(
        params, CFG, jnp.asarray([toks], jnp.int32)
    )[0, -1]
    seq = model_mod.decode_sequence(params, CFG, toks, use_pallas=False)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(batched), atol=1e-4)


def test_pallas_decode_matches_jnp_decode(params):
    toks = [1, 2, 3, 250]
    a = model_mod.decode_sequence(params, CFG, toks, use_pallas=False)
    b = model_mod.decode_sequence(params, CFG, toks, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_q8_decode_within_quant_tolerance(params):
    toks = [9, 8, 7]
    kc, vc = model_mod.empty_cache(CFG)
    packed = model_mod.pack_params_q8(params, CFG)
    lf, lq = None, None
    kcq, vcq = kc, vc
    for i, t in enumerate(toks):
        ti = jnp.asarray(t, jnp.int32)
        pi = jnp.asarray(i, jnp.int32)
        lf, kc, vc = model_mod.decode_step(params, CFG, ti, pi, kc, vc, use_pallas=False)
        lq, kcq, vcq = model_mod.decode_step_q8(packed, CFG, ti, pi, kcq, vcq)
    diff = float(jnp.max(jnp.abs(lf - lq)))
    scale = float(jnp.max(jnp.abs(lf)))
    assert diff > 0.0, "q8 path must quantize"
    assert diff < 0.35 * max(scale, 1.0), f"q8 drift too large: {diff} vs {scale}"


def test_loss_decreases_with_few_steps():
    """Tiny smoke-train: 12 steps must reduce loss on a repetitive batch."""
    from compile import train as train_mod

    cfg = dict(CFG)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    opt = train_mod.adamw_init(params)
    tok = np.tile(np.asarray([10, 20, 30, 40], np.int32), 9)[: 32 + 1]
    batch = jnp.asarray(np.stack([tok] * 4))
    lg = jax.jit(jax.value_and_grad(lambda p, b: model_mod.loss_fn(p, cfg, b)))
    first, last = None, None
    for _ in range(12):
        loss, grads = lg(params, batch)
        params, opt = train_mod.adamw_step(params, opt, grads)
        first = first if first is not None else float(loss)
        last = float(loss)
    assert last < first * 0.9, (first, last)


def test_cache_shape(params):
    kc, vc = model_mod.empty_cache(CFG)
    assert kc.shape == (CFG["n_layers"], CFG["max_seq_len"], CFG["n_heads"],
                        CFG["d_model"] // CFG["n_heads"])
    assert kc.shape == vc.shape
